"""Multi-chain scaling: per-chain iteration cost vs chain count C.

The multichain driver vmaps the FULL hybrid iteration over a chain axis,
so C chains share one jitted step: the uncollapsed sweeps batch into
larger matmuls and the (serial) collapsed tail scans run as one batched
scan. On one device the per-chain cost should therefore fall well below
Cx a single chain until the FLOP side saturates — that amortization
curve is what this benchmark measures (artifacts/multichain_scaling.csv).

  python benchmarks/multichain_scaling.py --N 240 --C 1 2 4 8
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp

from repro.core.ibp import (
    IBPHypers,
    hybrid_iteration_multichain,
    init_multichain,
)
from repro.data import cambridge_data, shard_rows

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")


def time_multichain(N: int, P: int, C: int, iters: int, L: int,
                    K_max: int) -> float:
    X, _, _ = cambridge_data(N=N, seed=0)
    Xs = jnp.asarray(shard_rows(X, P))
    hyp = IBPHypers()
    gs, ss = init_multichain(jax.random.key(0), Xs, C, K_max, K_tail=8,
                             K_init=4)
    gs, ss = hybrid_iteration_multichain(Xs, gs, ss, hyp, L=L, N_global=N)
    jax.block_until_ready(ss.Z)  # compile
    t0 = time.time()
    for _ in range(iters):
        gs, ss = hybrid_iteration_multichain(Xs, gs, ss, hyp, L=L,
                                             N_global=N)
    jax.block_until_ready(ss.Z)
    return (time.time() - t0) / iters


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--N", type=int, default=240)
    ap.add_argument("--P", type=int, default=4)
    ap.add_argument("--iters", type=int, default=10)
    ap.add_argument("--L", type=int, default=5)
    ap.add_argument("--K-max", type=int, default=24)
    ap.add_argument("--C", type=int, nargs="+", default=[1, 2, 4, 8])
    args = ap.parse_args(argv)

    rows, lines = [], []
    # amortization is defined vs a SINGLE chain — time C=1 for the
    # baseline even when it is not in the requested sweep
    base = time_multichain(args.N, args.P, 1, args.iters, args.L,
                           args.K_max)
    for C in args.C:
        s = (base if C == 1 else
             time_multichain(args.N, args.P, C, args.iters, args.L,
                             args.K_max))
        per_chain = s / C
        eff = base / per_chain  # >1: amortization from chain batching
        rows.append((C, s, per_chain, eff))
        lines.append(
            f"multichain__C{C},{s * 1e6:.0f},"
            f"per_chain_us={per_chain * 1e6:.0f};eff={eff:.2f};"
            f"N={args.N};P={args.P};L={args.L}"
        )
        print(lines[-1], flush=True)

    os.makedirs(ART, exist_ok=True)
    out = os.path.join(ART, "multichain_scaling.csv")
    with open(out, "w") as fh:
        fh.write("C,s_per_iter,s_per_chain_iter,amortization\n")
        for C, s, pc, eff in rows:
            fh.write(f"{C},{s:.4f},{pc:.4f},{eff:.2f}\n")
    print(f"-> {out}")
    return lines


if __name__ == "__main__":
    main()
