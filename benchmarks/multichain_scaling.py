"""Multi-chain scaling: per-chain iteration cost vs chain count C.

Two chain layouts of the composable sampler API (DESIGN.md §13):

* ``chains="vmap"`` — C chains share one jitted step on one device: the
  uncollapsed sweeps batch into larger matmuls and the (serial) collapsed
  tail scans run as one batched scan, so per-chain cost falls well below
  Cx a single chain until the FLOP side saturates. That amortization
  curve is the main measurement (artifacts/multichain_scaling.csv).
* ``chains="mesh"`` (``--mesh``) — the same C chains as a REAL mesh axis
  (C forced host devices, subprocess via benchmarks/_hostdev). On a
  shared-core CPU box this measures the per-device dispatch/collective
  overhead of the composed path, not speedup — it exists to keep the
  mesh layout's cost visible in the perf trajectory.

  python benchmarks/multichain_scaling.py --N 240 --C 1 2 4 8
"""
from __future__ import annotations

import argparse
import os
import time

import jax

from benchmarks._hostdev import run_hostdev_json
from repro.core.ibp import IBPHypers, SamplerSpec, build_sampler
from repro.data import cambridge_data

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")


def _spec(P: int, C: int, L: int, K_max: int, chains: str) -> SamplerSpec:
    return SamplerSpec(P=P, K_max=K_max, K_tail=8, K_init=4, L=L,
                       chains=chains, n_chains=C)


def time_multichain(N: int, P: int, C: int, iters: int, L: int,
                    K_max: int) -> float:
    X, _, _ = cambridge_data(N=N, seed=0)
    s = build_sampler(_spec(P, C, L, K_max, "vmap"), IBPHypers(), X)
    gs, st = s.init(jax.random.key(0))
    gs, st = s.step(gs, st)
    jax.block_until_ready(st.Z)  # compile
    t0 = time.time()
    for _ in range(iters):
        gs, st = s.step(gs, st)
    jax.block_until_ready(st.Z)
    return (time.time() - t0) / iters


def time_mesh_chains(N: int, P: int, C: int, iters: int, L: int,
                     K_max: int) -> float | None:
    """chains="mesh" x data="vmap" on C forced host devices (subprocess)."""
    code = f"""
        import json, time, jax
        from repro.data import cambridge_data
        from repro.core.ibp import IBPHypers, SamplerSpec, build_sampler
        X, _, _ = cambridge_data(N={N}, seed=0)
        spec = SamplerSpec(P={P}, K_max={K_max}, K_tail=8, K_init=4, L={L},
                           chains="mesh", data="vmap", n_chains={C})
        s = build_sampler(spec, IBPHypers(), X)
        gs, st = s.init(jax.random.key(0))
        gs, st = s.step(gs, st)
        jax.block_until_ready(st[0])
        t0 = time.time()
        for _ in range({iters}):
            gs, st = s.step(gs, st)
        jax.block_until_ready(st[0])
        print("BENCH_JSON:" + json.dumps({{"s": (time.time() - t0) / {iters}}}))
    """
    d = run_hostdev_json(code, C)
    return None if d is None else float(d["s"])


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--N", type=int, default=240)
    ap.add_argument("--P", type=int, default=4)
    ap.add_argument("--iters", type=int, default=10)
    ap.add_argument("--L", type=int, default=5)
    ap.add_argument("--K-max", type=int, default=24)
    ap.add_argument("--C", type=int, nargs="+", default=[1, 2, 4, 8])
    ap.add_argument("--mesh", action="store_true",
                    help="also time chains='mesh' on C forced host devices")
    args = ap.parse_args(argv)

    rows, lines = [], []
    # amortization is defined vs a SINGLE chain — time C=1 for the
    # baseline even when it is not in the requested sweep
    base = time_multichain(args.N, args.P, 1, args.iters, args.L,
                           args.K_max)
    for C in args.C:
        s = (base if C == 1 else
             time_multichain(args.N, args.P, C, args.iters, args.L,
                             args.K_max))
        per_chain = s / C
        eff = base / per_chain  # >1: amortization from chain batching
        rows.append(("vmap", C, s, per_chain, eff))
        lines.append(
            f"multichain__C{C},{s * 1e6:.0f},"
            f"per_chain_us={per_chain * 1e6:.0f};eff={eff:.2f};"
            f"N={args.N};P={args.P};L={args.L}"
        )
        print(lines[-1], flush=True)

    if args.mesh:
        for C in args.C:
            s = time_mesh_chains(args.N, args.P, C, args.iters, args.L,
                                 args.K_max)
            if s is None:
                continue
            rows.append(("mesh", C, s, s / C, base / (s / C)))
            lines.append(
                f"meshchains__C{C},{s * 1e6:.0f},"
                f"per_chain_us={s / C * 1e6:.0f};N={args.N};P={args.P}"
            )
            print(lines[-1], flush=True)

    os.makedirs(ART, exist_ok=True)
    out = os.path.join(ART, "multichain_scaling.csv")
    with open(out, "w") as fh:
        fh.write("chains,C,s_per_iter,s_per_chain_iter,amortization\n")
        for layout, C, s, pc, eff in rows:
            fh.write(f"{layout},{C},{s:.4f},{pc:.4f},{eff:.2f}\n")
    print(f"-> {out}")
    return lines


if __name__ == "__main__":
    main()
