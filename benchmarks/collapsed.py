"""Collapsed-sampler perf trajectory: the numbers behind BENCH_<date>.json.

Four measurements (ISSUE 2, 4 / DESIGN.md §12, §14):

* ``bench_collapsed``  — full collapsed sweep rows/s, ref (fresh O(K^3)
  factorization per row, the seed path) vs fast (rank-one Cholesky carry),
  at K_max ∈ {16, 32, 64}. The speedup column is the PR-2 headline number;
  the ref/fast equivalence test (tests/test_collapsed_fast.py) certifies
  it is not bought with approximation.
* ``bench_occupancy`` — the occupancy-adaptive packing trajectory: fast
  sweep rows/s, unpacked (k_live_buckets="off", every dense op at the
  K_max pad) vs packed (K_live bucket + carried G = HH^T), at fixed
  K_max with planted K_plus ∈ {4, 8, 16, 32, 56} live features. The
  ``packed_speedup`` column at K_plus=8 is the PR-4 headline number and
  the CI ``bench-smoke`` gate (packed >= 1.5x unpacked there).
* ``bench_uncollapsed`` — uncollapsed sweep rows/s per backend (jnp vs
  pallas), at the SAME row count for both backends so the comparison is
  apples-to-apples. On CPU the Pallas kernel executes in interpret mode
  (flagged in the payload), so both backends run at the interpret-sized
  row count there; on TPU both run at full N.
* ``bench_hybrid_sync`` — full hybrid iteration wall time, staged vs fused
  master sync, on P forced host devices in a subprocess (same pattern as
  benchmarks/scaling.py; shared-core, so it measures collective count
  overhead, not speedup).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._hostdev import run_hostdev_json


def _sweep_time(backend: str, X, K_max: int, refresh: int, iters: int,
                warm: int, k_live: str = "off",
                K_init: int = 8) -> tuple[float, int]:
    from repro.core.ibp import IBPHypers, collapsed_sweep
    from repro.core.ibp.state import init_state

    hyp = IBPHypers()
    N = X.shape[0]
    st = init_state(jax.random.key(0), N, X.shape[1], K_max=K_max,
                    K_init=K_init)
    for _ in range(warm):
        st = collapsed_sweep(st, X, hyp, backend=backend,
                             refresh_every=refresh, k_live_buckets=k_live)
    jax.block_until_ready(st.Z)
    t0 = time.time()
    for _ in range(iters):
        st = collapsed_sweep(st, X, hyp, backend=backend,
                             refresh_every=refresh, k_live_buckets=k_live)
    jax.block_until_ready(st.Z)
    return (time.time() - t0) / iters, int(st.active.sum())


def _data(N: int, D: int):
    from repro.data import cambridge_data

    X, _, _ = cambridge_data(N=N, sigma_n=0.4, seed=1)
    reps = -(-D // X.shape[1])  # ceil
    return jnp.asarray(np.tile(X, (1, reps))[:, :D].astype(np.float32))


def bench_collapsed(N: int, D: int, Ks, refresh: int, iters: int,
                    warm: int, repeats: int = 2) -> list[dict]:
    """rows/s of the full collapsed sweep, ref vs fast, per K_max."""
    X = _data(N, D)
    out = []
    for K in Ks:
        t_ref = min(_sweep_time("ref", X, K, refresh, iters, warm)[0]
                    for _ in range(repeats))
        t_fast, k_plus = min(
            (_sweep_time("fast", X, K, refresh, iters, warm)
             for _ in range(repeats)),
            key=lambda r: r[0],
        )
        out.append({
            "K_max": K,
            "K_plus": k_plus,
            "ref_rows_per_s": N / t_ref,
            "fast_rows_per_s": N / t_fast,
            "ref_ms_per_sweep": t_ref * 1e3,
            "fast_ms_per_sweep": t_fast * 1e3,
            "speedup": t_ref / t_fast,
        })
    return out


def _occ_case(N: int, D: int, K_max: int, kp: int):
    """Planted K_plus-feature data + a state STARTED AT the planted
    assignment, so the chain sits at the posterior mode and occupancy
    stays pinned near K_plus (a cold start would birth its way to a much
    larger K⁺ while fitting, defeating the low-occupancy measurement)."""
    import dataclasses

    from repro.core.ibp.state import init_state

    rng = np.random.default_rng(kp)
    Zt = (rng.random((N, kp)) < 0.5).astype(np.float32)
    Zt[:, 0] = 1.0  # no dead planted columns
    At = rng.standard_normal((kp, D)).astype(np.float32) * 2.0
    X = jnp.asarray(Zt @ At + 0.3 * rng.standard_normal(
        (N, D)).astype(np.float32))
    st = init_state(jax.random.key(0), N, D, K_max=K_max, K_init=kp,
                    alpha=0.5)
    Z0 = jnp.zeros((N, K_max), jnp.float32).at[:, :kp].set(jnp.asarray(Zt))
    return X, dataclasses.replace(st, Z=Z0)


def _occ_sweep_time(X, st0, refresh: int, iters: int, warm: int,
                    k_live: str) -> tuple[float, int]:
    from repro.core.ibp import IBPHypers, collapsed_sweep

    hyp = IBPHypers(resample_alpha=False)  # pinned small alpha: rare births
    st = st0
    for _ in range(warm):
        st = collapsed_sweep(st, X, hyp, backend="fast",
                             refresh_every=refresh, k_live_buckets=k_live)
    jax.block_until_ready(st.Z)
    t0 = time.time()
    for _ in range(iters):
        st = collapsed_sweep(st, X, hyp, backend="fast",
                             refresh_every=refresh, k_live_buckets=k_live)
    jax.block_until_ready(st.Z)
    return (time.time() - t0) / iters, int(st.active.sum())


def bench_occupancy(N: int, D: int, K_max: int, kplus_list, refresh: int,
                    iters: int, warm: int, repeats: int = 2) -> list[dict]:
    """Packed vs unpacked fast sweep rows/s across occupancy (DESIGN.md §14).

    The data is PLANTED with K_plus well-separated features and the
    chain starts AT the planted assignment, so occupancy stays pinned
    near the target while K_max provides the fixed pad — exactly the
    low-occupancy regime (K_plus << K_max) the packing targets. The
    achieved post-warmup K_plus is recorded next to the target.
    """
    out = []
    for kp in kplus_list:
        X, st0 = _occ_case(N, D, K_max, kp)
        # interleave the two variants across repeats (min of each): a
        # machine-load drift then biases both sides equally instead of
        # whichever variant ran last
        t_off = t_on = float("inf")
        k_plus = 0
        for _ in range(repeats):
            t_off = min(t_off,
                        _occ_sweep_time(X, st0, refresh, iters, warm,
                                        "off")[0])
            t, k = _occ_sweep_time(X, st0, refresh, iters, warm, "on")
            if t < t_on:
                t_on, k_plus = t, k
        out.append({
            "K_max": K_max,
            "K_plus_target": kp,
            "K_plus": k_plus,
            "unpacked_rows_per_s": N / t_off,
            "packed_rows_per_s": N / t_on,
            "unpacked_ms_per_sweep": t_off * 1e3,
            "packed_ms_per_sweep": t_on * 1e3,
            "packed_speedup": t_off / t_on,
        })
    return out


def bench_uncollapsed(N: int, D: int, K: int, iters: int,
                      pallas_rows: int = 128) -> list[dict]:
    """rows/s of one uncollapsed Z sweep per backend, SAME rows for both.

    On CPU the Pallas kernel runs in interpret mode (Python per grid
    cell), so both backends are timed at the interpret-sized row count —
    comparable rows/s, at the price of under-utilizing the jnp path. On
    TPU both run at the full N.
    """
    from repro.core.ibp.sweeps import uncollapsed_sweep

    rng = np.random.default_rng(0)
    A = jnp.asarray(rng.standard_normal((K, D)), jnp.float32)
    pi = jnp.full((K,), 0.3, jnp.float32)
    act = jnp.ones((K,), jnp.float32)
    interpreted = jax.default_backend() != "tpu"
    n = min(N, pallas_rows) if interpreted else N
    X = jnp.asarray(rng.standard_normal((n, D)), jnp.float32)
    Z0 = jnp.asarray((rng.random((n, K)) < 0.3), jnp.float32)
    out = []
    for backend in ("jnp", "pallas"):
        f = jax.jit(lambda Z, k, be=backend: uncollapsed_sweep(
            X, Z, A, pi, act, jnp.float32(1.0), k, backend=be))
        Z2 = jax.block_until_ready(f(Z0, jax.random.key(0)))
        t0 = time.time()
        for i in range(iters):
            Z2 = f(Z2, jax.random.key(i))
        jax.block_until_ready(Z2)
        dt = (time.time() - t0) / iters
        out.append({
            "backend": backend,
            "rows": n,
            "rows_per_s": n / dt,
            "interpreted": backend == "pallas" and interpreted,
        })
    return out


def bench_hybrid_sync(N: int, P: int, iters: int, K_max: int = 32,
                      L: int = 2) -> dict | None:
    """staged vs fused master sync, P forced host devices (subprocess)."""
    code = f"""
        import json, time, jax
        from repro.data import cambridge_data
        from repro.core.ibp import IBPHypers, SamplerSpec, build_sampler
        X, _, _ = cambridge_data(N={N}, seed=0)
        out = {{}}
        for sync in ("staged", "fused"):
            spec = SamplerSpec(P={P}, K_max={K_max}, K_tail=8, K_init=4,
                               L={L}, data="shardmap", sync=sync)
            s = build_sampler(spec, IBPHypers(), X)
            gs, st = s.init(jax.random.key(0))
            gs, st = s.step(gs, st)
            jax.block_until_ready(st[0])
            t0 = time.time()
            for _ in range({iters}):
                gs, st = s.step(gs, st)
            jax.block_until_ready(st[0])
            out[sync + "_s"] = (time.time() - t0) / {iters}
        print("BENCH_JSON:" + json.dumps(out))
    """
    d = run_hostdev_json(code, P)
    if d is not None:
        d.update({"P": P, "N": N, "K_max": K_max, "L": L})
    return d


def main(argv=None) -> tuple[list[str], dict]:
    """Returns (csv_lines, BENCH payload)."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--N", type=int, default=512)
    ap.add_argument("--D", type=int, default=64)
    ap.add_argument("--Ks", type=int, nargs="+", default=[16, 32, 64])
    ap.add_argument("--refresh", type=int, default=64)
    ap.add_argument("--iters", type=int, default=5)
    ap.add_argument("--warm", type=int, default=3)
    ap.add_argument("--repeats", type=int, default=3,
                    help="take the min over this many timing repeats "
                         "(shared-CPU noise floor)")
    ap.add_argument("--occ-K-max", type=int, default=64,
                    help="fixed K_max pad of the occupancy sweep")
    ap.add_argument("--occ-Kplus", type=int, nargs="+",
                    default=[4, 8, 16, 32, 56],
                    help="planted live-feature counts of the occupancy "
                         "sweep (packed vs unpacked fast)")
    ap.add_argument("--occ-N", type=int, default=None,
                    help="occupancy-sweep rows (default: --N). Unlike the "
                         "ref-vs-fast section there is no O(K^3) path "
                         "here, so smoke can afford real sizes — tiny "
                         "sweeps drown the packed win in per-sweep "
                         "dispatch overhead")
    ap.add_argument("--occ-D", type=int, default=None,
                    help="occupancy-sweep feature dim (default: --D)")
    ap.add_argument("--occ-iters", type=int, default=None,
                    help="occupancy-sweep timed sweeps per repeat "
                         "(default: --iters); the packed-vs-unpacked "
                         "ratio gates CI, so it gets enough sweeps to "
                         "sit at steady state even in smoke")
    ap.add_argument("--skip-occupancy", action="store_true")
    ap.add_argument("--skip-hybrid-sync", action="store_true")
    ap.add_argument("--P", type=int, default=4)
    args = ap.parse_args(argv)

    csv: list[str] = []
    payload: dict = {
        "collapsed_sweep": {
            "N": args.N, "D": args.D, "refresh_every": args.refresh,
            "results": bench_collapsed(args.N, args.D, args.Ks, args.refresh,
                                       args.iters, args.warm,
                                       repeats=args.repeats),
        },
    }
    for r in payload["collapsed_sweep"]["results"]:
        csv.append(
            f"collapsed_sweep__K{r['K_max']},"
            f"{r['fast_ms_per_sweep'] * 1e3:.0f},"
            f"ref_ms={r['ref_ms_per_sweep']:.1f};speedup={r['speedup']:.2f}x"
        )
        print(csv[-1], flush=True)

    if not args.skip_occupancy:
        occ_N = args.occ_N or args.N
        occ_D = args.occ_D or args.D
        occ_iters = args.occ_iters or args.iters
        payload["occupancy_sweep"] = {
            "N": occ_N, "D": occ_D, "refresh_every": args.refresh,
            "results": bench_occupancy(occ_N, occ_D, args.occ_K_max,
                                       args.occ_Kplus, args.refresh,
                                       occ_iters, args.warm,
                                       repeats=args.repeats),
        }
        for r in payload["occupancy_sweep"]["results"]:
            csv.append(
                f"occupancy_sweep__K{r['K_max']}_Kp{r['K_plus_target']},"
                f"{r['packed_ms_per_sweep'] * 1e3:.0f},"
                f"unpacked_ms={r['unpacked_ms_per_sweep']:.1f};"
                f"packed_speedup={r['packed_speedup']:.2f}x"
            )
            print(csv[-1], flush=True)

    payload["uncollapsed_sweep"] = {
        "D": args.D, "K": max(args.Ks),
        "results": bench_uncollapsed(args.N, args.D, max(args.Ks),
                                     args.iters),
    }
    for r in payload["uncollapsed_sweep"]["results"]:
        csv.append(
            f"uncollapsed_sweep__{r['backend']},"
            f"{r['rows'] / r['rows_per_s'] * 1e6:.0f},"
            f"rows_per_s={r['rows_per_s']:.0f}"
            f"{';interpreted' if r['interpreted'] else ''}"
        )
        print(csv[-1], flush=True)

    if not args.skip_hybrid_sync:
        hs = bench_hybrid_sync(min(args.N, 256), args.P, args.iters)
        if hs:
            payload["hybrid_sync"] = hs
            csv.append(
                f"hybrid_sync__P{hs['P']},"
                f"{hs['staged_s'] * 1e6:.0f},"
                f"fused_us={hs['fused_s'] * 1e6:.0f}"
            )
            print(csv[-1], flush=True)
    return csv, payload


if __name__ == "__main__":
    main()
