"""Perf-hillclimb harness (§Perf): measure a cell's roofline terms under
config overrides and log hypothesis -> before/after to artifacts/perf_log.jsonl.

    PYTHONPATH=src python -m benchmarks.hillclimb --arch deepseek-v2-236b \
        --shape train_4k --tag moe_gather --set moe_impl=gather
    PYTHONPATH=src python -m benchmarks.hillclimb --arch deepseek-v2-236b \
        --shape train_4k --tag moe_a2a --set moe_impl=a2a

Measurement = the same probe-extrapolation the roofline table uses (two
reduced UNROLLED depths; per-layer marginal x full depth), so before/after
deltas are apples-to-apples with §Roofline.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


def measure(arch: str, shape_name: str, overrides: dict, mesh_name="pod1"):
    import jax
    from repro.configs import ALL_SHAPES, get_config
    from repro.launch import dryrun
    from repro.launch.specs import abstract_model, param_bytes
    from repro.parallel.mesh import make_production_mesh
    from repro import compat

    shape = next(s for s in ALL_SHAPES if s.name == shape_name)
    cfg = get_config(arch)
    pstruct, _ = abstract_model(cfg, serve=shape.mode != "train")
    full_pbytes = param_bytes(pstruct, 2)
    L_full = cfg.n_layers
    L1, L2 = dryrun._probe_depths(cfg)
    mesh = make_production_mesh(multi_pod=(mesh_name == "pod2"))
    probes = {}
    for L in (L1, L2):
        sub = dict(overrides, n_layers=L, unroll_layers=True)
        if cfg.family == "encdec":
            sub["n_enc_layers"] = L
        cfg_l = dataclasses.replace(cfg, **sub)
        t0 = time.time()
        with compat.set_mesh(mesh):
            fn, args = dryrun.build_step(cfg_l, shape, mesh,
                                         force_param_bytes=full_pbytes)
            compiled = fn.lower(*args).compile()
            cost = compiled.cost_analysis()
            hlo = compiled.as_text()
        coll = dryrun.collective_bytes(hlo)
        probes[L] = {
            "flops": float(cost.get("flops", -1.0)),
            "bytes": float(cost.get("bytes accessed", -1.0)),
            "coll": float(coll["total"]),
            "coll_by_kind": {k: coll[k] for k in dryrun.COLLECTIVE_OPS},
            "compile_s": round(time.time() - t0, 1),
        }
    out = {}
    for key in ("flops", "bytes", "coll"):
        a, b = probes[L1][key], probes[L2][key]
        slope = max((b - a) / (L2 - L1), 0.0)
        out[key] = a + (L_full - L1) * slope
    terms = {
        "compute_s": out["flops"] / PEAK_FLOPS,
        "memory_s": out["bytes"] / HBM_BW,
        "collective_s": out["coll"] / LINK_BW,
    }
    terms["t_star_s"] = max(terms.values())
    terms["dominant"] = max(terms, key=lambda k: terms[k]
                            if k.endswith("_s") and k != "t_star_s" else -1)
    # per-kind collective extrapolation for the dominant-term breakdown
    kinds = {}
    for k in probes[L1]["coll_by_kind"]:
        a = probes[L1]["coll_by_kind"][k]
        b = probes[L2]["coll_by_kind"][k]
        kinds[k] = a + (L_full - L1) * max((b - a) / (L2 - L1), 0.0)
    return {"probes": {str(k): v for k, v in probes.items()},
            "extrapolated": out, "terms": terms, "coll_by_kind": kinds}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="pod1")
    ap.add_argument("--tag", required=True)
    ap.add_argument("--hypothesis", default="")
    ap.add_argument("--set", nargs="*", default=[],
                    help="cfg overrides: key=value (int/float/str inferred)")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                continue
        overrides[k] = v

    rec = {"arch": args.arch, "shape": args.shape, "mesh": args.mesh,
           "tag": args.tag, "hypothesis": args.hypothesis,
           "overrides": overrides}
    rec.update(measure(args.arch, args.shape, overrides, args.mesh))

    os.makedirs(ART, exist_ok=True)
    with open(os.path.join(ART, "perf_log.jsonl"), "a") as fh:
        fh.write(json.dumps(rec) + "\n")

    t = rec["terms"]
    print(f"\n[{args.tag}] {args.arch} x {args.shape} @ {args.mesh}")
    print(f"  compute    {t['compute_s']:10.3f} s")
    print(f"  memory     {t['memory_s']:10.3f} s")
    print(f"  collective {t['collective_s']:10.3f} s   <- breakdown:")
    for k, v in sorted(rec["coll_by_kind"].items(), key=lambda kv: -kv[1]):
        if v > 0:
            print(f"      {k:20s} {v / 2**30:10.2f} GiB")
    print(f"  T* = {t['t_star_s']:.3f} s  dominant = {t['dominant']}")


if __name__ == "__main__":
    main()
