"""Roofline analysis (deliverable g): three terms per (arch x shape) cell from
the compiled dry-run artifacts, corrected for scan-over-layers.

XLA-CPU ``cost_analysis`` counts a while-loop body ONCE regardless of trip
count, so the full-depth numbers under scan-over-layers undercount by ~L.
The depth probes (dryrun.py --probe) lower each cell at two reduced depths;
we extrapolate linearly:

    total(L) ~= probe(L1) + (L - L1) * (probe(L2) - probe(L1)) / (L2 - L1)

Hardware model (TPU v5e target):
    peak bf16    197 TFLOP/s / chip
    HBM bw       819 GB/s / chip
    ICI link bw  ~50 GB/s / link (single-link serialization model)

Terms (seconds, per step, per chip — SPMD means per-chip time is step time):
    compute_s    = HLO_flops_per_dev / peak
    memory_s     = HLO_bytes_per_dev / hbm_bw
    collective_s = collective_bytes_per_dev / link_bw
    T*           = max(terms)          (roofline-achievable step time)
    mfu_roofline = model_flops_per_dev / peak / T*   (the §Perf score)
"""
from __future__ import annotations

import glob
import json
import os

from repro.configs import ALL_SHAPES, ARCH_IDS, get_config

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9
CHIPS = {"pod1": 256, "pod2": 512}

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def _layers_for(cfg) -> float:
    """Effective scan trip count matching the probe depths."""
    return float(cfg.n_layers)


def load_cell(arch: str, shape_name: str, mesh: str) -> dict | None:
    p = os.path.join(ART, f"{arch}__{shape_name}__{mesh}.json")
    if not os.path.exists(p):
        return None
    with open(p) as fh:
        return json.load(fh)


def load_probe(arch: str, shape_name: str, mesh: str = "pod1") -> dict | None:
    p = os.path.join(ART, f"probe__{arch}__{shape_name}__{mesh}.json")
    if not os.path.exists(p):
        return None
    with open(p) as fh:
        return json.load(fh)


def extrapolate(probe: dict, L: float, cell: dict) -> dict[str, float]:
    """total(L) ≈ probe(L1) + (L-L1)·slope with slope from unrolled probes.

    Guards: a non-positive slope means the probe failed to expose the marginal
    layer cost (or the quantity really is depth-independent) — clamp slope at
    0 and never report less than the raw full-depth cell measurement.
    """
    L1, L2 = probe["L1"], probe["L2"]
    p1, p2 = probe["probes"][str(L1)], probe["probes"][str(L2)]
    raw = {
        "flops": cell["flops"],
        "bytes": cell["bytes_accessed"],
        "coll": cell["collectives"]["total"],
    }
    out = {}
    for k_src, k_dst in [("flops", "flops"),
                         ("bytes_accessed", "bytes"),
                         ("collective_total", "coll")]:
        a, b = p1[k_src], p2[k_src]
        slope = max((b - a) / (L2 - L1), 0.0)
        # trust the probe (it reflects the current code); the raw full-depth
        # number only floors pathological (zero-slope) extrapolations at a
        # fraction of itself — raw undercounts by ~L under scan, so a fresh
        # probe is always the better estimate
        out[k_dst] = max(a + (L - L1) * slope, 0.0)
        if out[k_dst] < raw[k_dst] / max(L, 1.0):
            out[k_dst] = raw[k_dst]
    return out


def model_flops_per_step(cfg, shape) -> float:
    """Useful model FLOPs per step, global: 6·N·D train, 2·N·D serve."""
    n = cfg.param_count_active()
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.mode == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    # decode: one token per sequence + KV-cache attention reads are
    # memory-bound, not matmul FLOPs
    return 2.0 * n * shape.global_batch


def analyze_cell(arch: str, shape, mesh: str = "pod1") -> dict | None:
    cell = load_cell(arch, shape.name, mesh)
    if cell is None or cell["status"] != "ok":
        return cell
    cfg = get_config(arch)
    probe = load_probe(arch, shape.name)
    chips = CHIPS[mesh]
    if probe and probe.get("status") == "ok":
        ex = extrapolate(probe, _layers_for(cfg), cell)
        src = "probe-extrapolated"
    else:
        ex = {
            "flops": cell["flops"],
            "bytes": cell["bytes_accessed"],
            "coll": cell["collectives"]["total"],
        }
        src = "raw (scan-undercounted)"

    compute_s = ex["flops"] / PEAK_FLOPS
    memory_s = ex["bytes"] / HBM_BW
    coll_s = ex["coll"] / LINK_BW
    t_star = max(compute_s, memory_s, coll_s)
    dom = max(
        [("compute", compute_s), ("memory", memory_s), ("collective", coll_s)],
        key=lambda kv: kv[1],
    )[0]
    mf = model_flops_per_step(cfg, shape) / chips
    mfu = mf / PEAK_FLOPS / t_star if t_star > 0 else 0.0
    return {
        "arch": arch,
        "shape": shape.name,
        "mesh": mesh,
        "source": src,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": coll_s,
        "t_star_s": t_star,
        "dominant": dom,
        "model_flops_per_dev": mf,
        "hlo_flops_per_dev": ex["flops"],
        "useful_ratio": mf / ex["flops"] if ex["flops"] > 0 else 0.0,
        "mfu_at_roofline": mfu,
        "memory_temp_gib": cell["memory"].get("temp_size_in_bytes", 0) / 2**30,
    }


RECOMMEND = {
    "compute": "compute-bound: already at the good end; next win is reducing "
               "redundant HLO flops (remat policy / fusing projections)",
    "memory": "HBM-bound: shrink bytes/step — fuse residual chains, bf16 "
              "everything feasible, cut remat rematerialization traffic",
    "collective": "ICI-bound: re-shard to cut all-gathers (FSDP prefetch, "
                  "SP boundaries), or overlap collectives with compute",
}


def full_table(mesh: str = "pod1") -> list[dict]:
    rows = []
    for arch in ARCH_IDS:
        for shape in ALL_SHAPES:
            r = analyze_cell(arch, shape, mesh)
            if r is None:
                continue
            rows.append(r)
    return rows


def render_markdown(rows: list[dict]) -> str:
    out = [
        "| arch | shape | compute_s | memory_s | collective_s | dominant | "
        "MODEL/HLO flops | MFU@roofline | note |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r.get("status") == "skipped":
            out.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | skipped | — | — | "
                f"{r['reason'][:60]} |"
            )
            continue
        if r.get("status") == "error":
            out.append(f"| {r['arch']} | {r['shape']} | ERROR |||||||")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.2e} | "
            f"{r['memory_s']:.2e} | {r['collective_s']:.2e} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{r['mfu_at_roofline'] * 100:.1f}% | "
            f"{RECOMMEND[r['dominant']][:40]}… |"
        )
    return "\n".join(out)


def main():
    rows = full_table("pod1")
    os.makedirs("artifacts", exist_ok=True)
    with open("artifacts/roofline.json", "w") as fh:
        json.dump(rows, fh, indent=1)
    ok = [r for r in rows if "dominant" in r]
    print(render_markdown(rows))
    print(f"\n{len(ok)} analyzed cells -> artifacts/roofline.json")
    # csv contract for benchmarks.run
    for r in ok:
        print(
            f"roofline__{r['arch']}__{r['shape']},"
            f"{r['t_star_s'] * 1e6:.1f},"
            f"dominant={r['dominant']};mfu={r['mfu_at_roofline']:.3f}"
        )


if __name__ == "__main__":
    main()
