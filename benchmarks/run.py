"""Benchmark harness: one entry per paper table/figure + framework benches.

``python -m benchmarks.run [--quick] [--only fig1,fig2,kernels,scaling,roofline]``

Prints a ``name,us_per_call,derived`` CSV block at the end (the harness
contract). Individual benchmarks are importable modules with their own CLIs
for full-size runs; this runner uses CPU-sized defaults.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def _section(title: str):
    print(f"\n===== {title} " + "=" * max(0, 60 - len(title)), flush=True)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smallest sizes (CI smoke)")
    ap.add_argument("--only", default="",
                    help="comma-separated subset of "
                         "fig1,fig2,kernels,scaling,roofline")
    args = ap.parse_args(argv)
    only = set(filter(None, args.only.split(",")))

    def want(name: str) -> bool:
        return not only or name in only

    csv: list[str] = []
    failures: list[str] = []
    t_all = time.time()

    if want("kernels"):
        _section("kernels: Pallas vs jnp-oracle + arithmetic intensity")
        from benchmarks import kernels
        try:
            csv += kernels.main(["--N", "1024"] if args.quick else [])
        except Exception:
            failures.append("kernels")
            traceback.print_exc()

    if want("fig1"):
        _section("fig1: convergence vs wall-clock (collapsed vs hybrid P)")
        from benchmarks import fig1_convergence
        try:
            fig1_args = (["--N", "120", "--iters", "30", "--eval-every", "10"]
                         if args.quick else
                         ["--N", "240", "--iters", "80", "--eval-every", "10"])
            csv += fig1_convergence.main(fig1_args)
        except Exception:
            failures.append("fig1")
            traceback.print_exc()

    if want("fig2"):
        _section("fig2: posterior feature recovery (Cambridge)")
        from benchmarks import fig2_features
        try:
            fig2_args = (["--N", "150", "--iters", "40"] if args.quick
                         else ["--N", "300", "--iters", "100"])
            csv += fig2_features.main(fig2_args)
        except Exception:
            failures.append("fig2")
            traceback.print_exc()

    if want("scaling"):
        _section("scaling: iteration time vs P (vmap + shard_map)")
        from benchmarks import scaling
        try:
            sc_args = ["--iters", "3", "--P", "1", "2", "4"] if args.quick \
                else ["--iters", "8", "--P", "1", "2", "4", "8"]
            csv += scaling.main(sc_args)
        except Exception:
            failures.append("scaling")
            traceback.print_exc()

    if want("roofline"):
        _section("roofline: 3-term analysis from dry-run artifacts")
        try:
            from benchmarks import roofline
            rows = roofline.full_table("pod1")
            ok = [r for r in rows if r and "dominant" in r]
            print(roofline.render_markdown(rows))
            for r in ok:
                csv.append(
                    f"roofline__{r['arch']}__{r['shape']},"
                    f"{r['t_star_s'] * 1e6:.1f},"
                    f"dominant={r['dominant']};mfu={r['mfu_at_roofline']:.3f}"
                )
            if not ok:
                print("(no dry-run artifacts found — run "
                      "`python -m repro.launch.dryrun --all` first)")
        except Exception:
            failures.append("roofline")
            traceback.print_exc()

    _section(f"CSV (total {time.time() - t_all:.0f}s)")
    print("name,us_per_call,derived")
    for line in csv:
        print(line)
    if failures:
        print(f"\nFAILED sections: {failures}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
