"""Benchmark harness: one entry per paper table/figure + framework benches.

``python -m benchmarks.run [--quick|--smoke] [--only fig1,fig2,kernels,collapsed,scaling,roofline]``

Prints a ``name,us_per_call,derived`` CSV block at the end (the harness
contract) and writes a machine-readable ``BENCH_<iso-date>.json`` at the
repo root (the durable perf trajectory: kernel timings as structured
JSON objects, collapsed sweep ref-vs-fast rows/s per K, the occupancy
sweep packed-vs-unpacked rows/s per K_plus, uncollapsed rows/s per
backend, hybrid staged-vs-fused sync). ``--smoke`` runs the kernels +
collapsed sections at tiny sizes and FAILS (exit 1) if any gate trips:
the fast collapsed row step below ``SMOKE_MIN_SPEEDUP``x ref at K=64,
the packed (occupancy-adaptive) fast path below
``SMOKE_MIN_PACKED_SPEEDUP``x the unpacked fast path at
K_max=64/K_plus=8, the fail-closed BENCH_*.json schema lint, or the
unified-core no-regression gate (both in
``benchmarks/bench_schema.py``) — the CI perf gates. A run also lints
its OWN payload before writing it, so a malformed section can never
enter the trajectory. Individual benchmarks are
importable modules with their own CLIs for full-size runs; this runner
uses CPU-sized defaults.
"""
from __future__ import annotations

import argparse
import datetime
import os
import sys
import time
import traceback

SMOKE_MIN_SPEEDUP = 2.0  # fast vs ref collapsed sweep at K=64, CPU
SMOKE_MIN_PACKED_SPEEDUP = 1.5  # packed vs unpacked fast at K=64/K+=8, CPU
SMOKE_MIN_SERVE_SPEEDUP = 3.0  # batched bank scoring vs the naive
#                                per-sample request loop at S=32/B=256/K=64
#                                (full runs measure ~5-8x; the gate leaves
#                                CI noise headroom)
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _section(title: str):
    print(f"\n===== {title} " + "=" * max(0, 60 - len(title)), flush=True)


def _write_bench_json(payload: dict) -> str:
    # merge, don't clobber: serve_ibp read-modify-writes its serving_loop
    # section into the SAME date-keyed file — sections this run did not
    # produce must survive (two writers, one durable trajectory; the
    # tolerant atomic merge is shared via checkpoint.update_json)
    from repro.checkpoint import update_json

    path = os.path.join(
        REPO_ROOT, f"BENCH_{datetime.date.today().isoformat()}.json"
    )
    update_json(path, lambda merged: {**merged, **payload})
    print(f"perf trajectory -> {path}", flush=True)
    return path


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smallest sizes for every section")
    ap.add_argument("--smoke", action="store_true",
                    help="CI perf smoke: kernels + collapsed only, tiny "
                         "sizes, enforce the fast>=2x ref gate at K=64")
    ap.add_argument("--only", default="",
                    help="comma-separated subset of "
                         "fig1,fig2,kernels,collapsed,predict,scaling,"
                         "roofline")
    args = ap.parse_args(argv)
    if args.smoke and not args.only:
        args.only = "kernels,collapsed,predict"
        args.quick = True
    only = set(filter(None, args.only.split(",")))

    def want(name: str) -> bool:
        return not only or name in only

    csv: list[str] = []
    failures: list[str] = []
    import jax

    bench: dict = {
        "date": datetime.date.today().isoformat(),
        "mode": "smoke" if args.smoke else ("quick" if args.quick else "full"),
        "jax_backend": jax.default_backend(),
        "device_count": jax.device_count(),
    }
    t_all = time.time()

    if want("kernels"):
        _section("kernels: Pallas vs jnp-oracle + arithmetic intensity")
        from benchmarks import kernels
        try:
            lines, results = kernels.main(["--N", "1024"] if args.quick
                                          else [])
            csv += lines
            bench["kernels"] = results  # structured objects, not csv strings
        except Exception:
            failures.append("kernels")
            traceback.print_exc()

    if want("collapsed"):
        _section("collapsed: O(K^3) ref vs rank-one-carry fast trajectory")
        from benchmarks import collapsed
        try:
            col_args = (["--N", "128", "--D", "32", "--Ks", "16", "64",
                         "--iters", "2", "--warm", "3",
                         "--occ-Kplus", "8", "--occ-N", "512",
                         "--occ-D", "64", "--occ-iters", "5",
                         "--repeats", "3",
                         "--skip-hybrid-sync"]
                        if args.smoke else
                        (["--N", "256", "--iters", "3", "--warm", "2",
                          "--occ-N", "512", "--occ-D", "64"]
                         if args.quick else []))
            lines, payload = collapsed.main(col_args)
            csv += lines
            bench.update(payload)
            k64 = [r for r in payload["collapsed_sweep"]["results"]
                   if r["K_max"] == 64]
            occ8 = [r for r in payload.get("occupancy_sweep",
                                           {}).get("results", [])
                    if r["K_max"] == 64 and r["K_plus_target"] == 8]
            if args.smoke:
                if not k64:  # fail closed: the gate must never be vacuous
                    failures.append("collapsed perf gate: no K=64 row")
                elif k64[0]["speedup"] < SMOKE_MIN_SPEEDUP:
                    failures.append(
                        f"collapsed perf gate: fast is "
                        f"{k64[0]['speedup']:.2f}x ref at K=64 "
                        f"(< {SMOKE_MIN_SPEEDUP}x)"
                    )
                # low-occupancy gate: packed must beat unpacked (DESIGN §14)
                if not occ8:  # fail closed here too
                    failures.append(
                        "occupancy perf gate: no K_max=64/K_plus=8 row")
                elif occ8[0]["packed_speedup"] < SMOKE_MIN_PACKED_SPEEDUP:
                    failures.append(
                        f"occupancy perf gate: packed fast is "
                        f"{occ8[0]['packed_speedup']:.2f}x unpacked at "
                        f"K_max=64/K_plus=8 (< {SMOKE_MIN_PACKED_SPEEDUP}x)"
                    )
                # unified-core no-regression gate (DESIGN.md §12): the
                # top-bucket unpacked timing must stay within noise of
                # the trajectory recorded with the pre-unification
                # dedicated unpacked carry
                from benchmarks import bench_schema
                failures += bench_schema.unpacked_core_regression(
                    payload.get("occupancy_sweep", {}),
                    skip_date=bench["date"])
        except Exception:
            failures.append("collapsed")
            traceback.print_exc()

    if args.smoke:
        # fail-closed schema lint over every committed BENCH_*.json —
        # a malformed trajectory file fails CI before it can poison the
        # perf-history consumers
        from benchmarks import bench_schema
        failures += bench_schema.lint_repo()

    if want("predict"):
        _section("predict: (S x B)-batched bank scoring vs naive loop")
        from benchmarks import predict as predict_bench
        try:
            pr_args = (["--required-only", "--reps", "2"] if args.smoke
                       else (["--Ss", "8", "--Bs", "64", "--Ks", "16",
                              "--reps", "2"] if args.quick else []))
            lines, payload = predict_bench.main(pr_args)
            csv += lines
            bench.update(payload)
            if args.smoke:
                req = [r for r in payload["predict_serving"]["results"]
                       if (r["S"], r["B"], r["K"]) == predict_bench.REQUIRED]
                if not req:  # fail closed, like the collapsed gates
                    failures.append(
                        "serving perf gate: no S=32/B=256/K=64 row")
                elif req[0]["speedup"] < SMOKE_MIN_SERVE_SPEEDUP:
                    failures.append(
                        f"serving perf gate: batched bank scoring is "
                        f"{req[0]['speedup']:.2f}x the naive per-sample "
                        f"request loop at S=32/B=256/K=64 "
                        f"(< {SMOKE_MIN_SERVE_SPEEDUP}x)"
                    )
        except Exception:
            failures.append("predict")
            traceback.print_exc()

    if want("fig1"):
        _section("fig1: convergence vs wall-clock (collapsed vs hybrid P)")
        from benchmarks import fig1_convergence
        try:
            fig1_args = (["--N", "120", "--iters", "30", "--eval-every", "10"]
                         if args.quick else
                         ["--N", "240", "--iters", "80", "--eval-every", "10"])
            csv += fig1_convergence.main(fig1_args)
        except Exception:
            failures.append("fig1")
            traceback.print_exc()

    if want("fig2"):
        _section("fig2: posterior feature recovery (Cambridge)")
        from benchmarks import fig2_features
        try:
            fig2_args = (["--N", "150", "--iters", "40"] if args.quick
                         else ["--N", "300", "--iters", "100"])
            csv += fig2_features.main(fig2_args)
        except Exception:
            failures.append("fig2")
            traceback.print_exc()

    if want("scaling"):
        _section("scaling: iteration time vs P (vmap + shard_map)")
        from benchmarks import scaling
        try:
            sc_args = ["--iters", "3", "--P", "1", "2", "4"] if args.quick \
                else ["--iters", "8", "--P", "1", "2", "4", "8"]
            csv += scaling.main(sc_args)
        except Exception:
            failures.append("scaling")
            traceback.print_exc()

    if want("roofline"):
        _section("roofline: 3-term analysis from dry-run artifacts")
        try:
            from benchmarks import roofline
            rows = roofline.full_table("pod1")
            ok = [r for r in rows if r and "dominant" in r]
            print(roofline.render_markdown(rows))
            for r in ok:
                csv.append(
                    f"roofline__{r['arch']}__{r['shape']},"
                    f"{r['t_star_s'] * 1e6:.1f},"
                    f"dominant={r['dominant']};mfu={r['mfu_at_roofline']:.3f}"
                )
            if not ok:
                print("(no dry-run artifacts found — run "
                      "`python -m repro.launch.dryrun --all` first)")
        except Exception:
            failures.append("roofline")
            traceback.print_exc()

    _section(f"CSV (total {time.time() - t_all:.0f}s)")
    print("name,us_per_call,derived")
    for line in csv:
        print(line)
    if ("collapsed_sweep" in bench or "kernels" in bench
            or "predict_serving" in bench):
        # never write a trajectory entry the lint would reject
        from benchmarks import bench_schema
        own_errs = bench_schema.lint_payload(bench, where="this-run")
        if own_errs:
            failures += own_errs
        else:
            _write_bench_json(bench)
    if failures:
        print(f"\nFAILED sections: {failures}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
