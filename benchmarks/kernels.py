"""Pallas-kernel microbenchmarks.

This container is CPU-only, so the kernels execute in interpret mode (Python
per grid cell) — wall time there measures nothing about TPU. What we CAN
measure structurally and report:

  * allclose vs the pure-jnp oracle at a production-ish shape (correctness
    at scale, not just the unit-test shapes);
  * the jnp reference path wall time on CPU (the baseline any TPU time would
    be compared against);
  * per-kernel arithmetic intensity (FLOPs / HBM bytes) at that shape from
    first principles — the quantity the BlockSpec tiling was designed
    around (see kernels/*/kernel.py docstrings).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.collapsed_row import (
    collapsed_row_flip,
    collapsed_row_flip_fast,
    collapsed_row_flip_ref,
)
from repro.kernels.feature_stats import feature_stats, feature_stats_ref
from repro.kernels.gaussian_sse import gaussian_sse, gaussian_sse_ref
from repro.kernels.gibbs_flip import gibbs_flip_core, gibbs_flip_ref


def _time(f, iters=5):
    jax.block_until_ready(f())
    t0 = time.time()
    for _ in range(iters):
        out = f()
    jax.block_until_ready(out)
    return (time.time() - t0) / iters


def _inputs(N, K, D, seed=0):
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.standard_normal((N, D)), jnp.float32)
    Z = jnp.asarray((rng.random((N, K)) < 0.3), jnp.float32)
    A = jnp.asarray(rng.standard_normal((K, D)), jnp.float32)
    act = jnp.ones((K,), jnp.float32)
    return X, Z, A, act, rng


def bench_gibbs_flip(N, K, D, interp_N=128):
    X, Z, A, act, rng = _inputs(N, K, D)
    lpi = jnp.asarray(rng.standard_normal(K), jnp.float32)
    u = jnp.asarray(rng.standard_normal((N, K)) * 2, jnp.float32)
    inv2s2 = jnp.float32(0.5)
    got = gibbs_flip_core(X[:interp_N], Z[:interp_N], A, lpi, act,
                          u[:interp_N], inv2s2)
    want = gibbs_flip_ref(X[:interp_N], Z[:interp_N], A, lpi, act,
                          u[:interp_N], inv2s2)
    assert bool(jnp.all(got == want))
    t_ref = _time(lambda: gibbs_flip_ref(X, Z, A, lpi, act, u, inv2s2))
    # per sweep: K sequential steps, each a rank-1 residual update (2ND) +
    # scoring (3ND); residual stays VMEM-resident -> bytes ~ X + Z(in/out) + A
    flops = 5.0 * N * D * K
    bytes_ = 4.0 * (N * D + 2 * N * K + K * D)
    return t_ref, flops / bytes_


def bench_feature_stats(N, K, D):
    X, Z, _, _, _ = _inputs(N, K, D, seed=1)
    ztz_k, ztx_k, m_k = feature_stats(X[:256], Z[:256])
    ztz_r, ztx_r, m_r = feature_stats_ref(X[:256], Z[:256])
    np.testing.assert_allclose(np.asarray(ztz_k), np.asarray(ztz_r), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(ztx_k), np.asarray(ztx_r),
                               rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(np.asarray(m_k), np.asarray(m_r))
    t_ref = _time(lambda: feature_stats_ref(X, Z))
    # fused: one pass over X and Z produces ZtZ, ZtX, m
    flops = 2.0 * N * K * (K + D)
    bytes_ = 4.0 * (N * D + N * K + K * K + K * D)
    return t_ref, flops / bytes_


def bench_collapsed_row(N, K, D):
    """The K-sequential collapsed bit-flip recurrence, scanned over N rows.

    Correctness: Pallas kernel (interpret on CPU) must match the jnp
    oracle bitwise at this shape. Perf: the ref (full-K, mean-carry) vs
    fast (packed-active, rss/rH-carry) flavors over an N-row scan — the
    "ref-vs-fast" column of the perf trajectory at the recurrence level.
    """
    rng = np.random.default_rng(3)
    Zb = (rng.random((4 * K, K)) < 0.3).astype(np.float32)
    W = (Zb.T @ Zb + 0.7 * np.eye(K)).astype(np.float32)
    M = jnp.asarray(np.linalg.inv(W), jnp.float32)
    H = jnp.asarray(
        np.linalg.solve(W, Zb.T @ rng.standard_normal((4 * K, D))),
        jnp.float32,
    )
    x = jnp.asarray(rng.standard_normal(D), jnp.float32)
    z0 = jnp.asarray((rng.random(K) < 0.3), jnp.float32)
    u = jnp.asarray(rng.standard_normal(K) * 2, jnp.float32)
    mm = jnp.asarray(Zb.sum(0), jnp.float32)
    act = jnp.ones((K,), jnp.float32)
    Nf, i2 = jnp.float32(N), jnp.float32(0.5)

    def start(z):
        v = M @ z
        return v, jnp.dot(z, v), z @ H

    v, q, mean = start(z0)
    base = (M, H, x, z0, v, q, mean, u, mm, act, Nf, i2)
    zr, *_ = collapsed_row_flip_ref(*base)
    zp, *_ = collapsed_row_flip(*base, flavor="pallas")
    zf, *_ = collapsed_row_flip_fast(*base)
    assert bool(jnp.all(zr == zp)), "pallas != ref"  # identical arithmetic
    # the packed flavor's float path may round a boundary accept differently
    # (documented; tests budget the same) — don't fail CI on one such bit
    assert int(jnp.sum(zr != zf)) <= 2, "fast diverged from ref beyond budget"

    def scan_with(flip):
        def f(z):
            def body(z, _):
                v, q, mean = start(z)
                z, _, _, _ = flip(M, H, x, z, v, q, mean, u, mm, act, Nf, i2)
                return z, None
            return jax.lax.scan(body, z, jnp.arange(N))[0]
        return jax.jit(f)

    f_ref = scan_with(collapsed_row_flip_ref)
    f_fast = scan_with(collapsed_row_flip_fast)
    t_ref = _time(lambda: f_ref(z0))
    t_fast = _time(lambda: f_fast(z0))
    # per bit: O(K) carry moves + scalar likelihood = ~6K flops; M, H, G
    # stay register/VMEM-resident across the whole K-loop
    flops = 6.0 * N * K * K
    bytes_ = 4.0 * (K * K + K * D + N * K)
    return t_ref, t_fast, flops / bytes_


def bench_gaussian_sse(N, K, D):
    X, Z, A, act, _ = _inputs(N, K, D, seed=2)
    s_k = gaussian_sse(X[:256], Z[:256], A, act)
    s_r = gaussian_sse_ref(X[:256], Z[:256], A, act)
    np.testing.assert_allclose(float(s_k), float(s_r), rtol=1e-4)
    t_ref = _time(lambda: gaussian_sse_ref(X, Z, A, act))
    # fused: residual never hits HBM (ref writes + rereads N*D)
    flops = 2.0 * N * K * D + 3.0 * N * D
    bytes_ = 4.0 * (N * D + N * K + K * D)
    return t_ref, flops / bytes_


def main(argv=None) -> tuple[list[str], list[dict]]:
    """Returns (csv_lines, results).

    ``results`` is the machine-readable form that lands in
    ``BENCH_*.json["kernels"]``: one JSON object per kernel with
    ``name``, ``us`` (jnp-reference wall time), ``allclose``,
    ``arith_intensity`` and a structured ``shape`` — replacing the old
    packed comma-string so the perf trajectory is machine-diffable.
    """
    ap = argparse.ArgumentParser()
    ap.add_argument("--N", type=int, default=4096)
    ap.add_argument("--K", type=int, default=64)
    ap.add_argument("--D", type=int, default=256)
    args = ap.parse_args(argv)
    N, K, D = args.N, args.K, args.D

    lines: list[str] = []
    results: list[dict] = []
    for name, fn in [("gibbs_flip", bench_gibbs_flip),
                     ("feature_stats", bench_feature_stats),
                     ("gaussian_sse", bench_gaussian_sse)]:
        t_ref, ai = fn(N, K, D)
        results.append({
            "name": name,
            "us": t_ref * 1e6,
            "allclose": True,
            "arith_intensity": ai,
            "shape": {"N": N, "K": K, "D": D},
        })
        lines.append(
            f"kernel__{name},{t_ref * 1e6:.0f},"
            f"allclose=ok;arith_intensity={ai:.1f};shape=N{N}xK{K}xD{D}"
        )
        print(lines[-1], flush=True)
    # collapsed_row: the row scan is serial, so bench at row-scan scale
    n_rows = min(N, 512)
    t_ref, t_fast, ai = bench_collapsed_row(n_rows, K, min(D, 64))
    results.append({
        "name": "collapsed_row",
        "us": t_ref * 1e6,
        "fast_us": t_fast * 1e6,
        "ref_vs_fast": t_ref / t_fast,
        "allclose": True,
        "arith_intensity": ai,
        "shape": {"N": n_rows, "K": K, "D": min(D, 64)},
    })
    lines.append(
        f"kernel__collapsed_row,{t_ref * 1e6:.0f},"
        f"allclose=ok;fast_us={t_fast * 1e6:.0f};"
        f"ref_vs_fast={t_ref / t_fast:.2f}x;"
        f"arith_intensity={ai:.1f};shape=N{n_rows}xK{K}xD{min(D, 64)}"
    )
    print(lines[-1], flush=True)
    return lines, results


if __name__ == "__main__":
    main()
