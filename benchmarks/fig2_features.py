"""Paper Fig. 2: posterior features recovered from the Cambridge data set.

Runs the collapsed sampler and the hybrid sampler (P=5) and compares the
posterior feature images A against the four ground-truth 6x6 base images
via greedy L2 matching. Artifacts: artifacts/fig2_true.npy,
fig2_collapsed.npy, fig2_hybrid.npy (+ ASCII rendering on stdout).
"""
from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ibp import (
    IBPHypers,
    SamplerSpec,
    build_sampler,
    collapsed_sweep,
    init_state,
)
from repro.core.ibp import math as ibm
from repro.core.ibp.diagnostics import match_features
from repro.data import cambridge_data
from repro.data.cambridge import CAMBRIDGE_FEATURES

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")


def posterior_features_collapsed(X, iters, K_max, seed):
    N, D = X.shape
    st = init_state(jax.random.key(seed), N, D, K_max, K_init=1)
    Xj = jnp.asarray(X)
    hyp = IBPHypers()
    for _ in range(iters):
        st = collapsed_sweep(st, Xj, hyp)
    ZtZ = (st.Z.T @ st.Z) * ibm.mask_outer(st.active)
    ZtX = (st.Z.T @ Xj) * st.active[:, None]
    # posterior MEAN of A given the final Z (Fig. 2 shows features, not draws)
    A, _ = ibm.a_posterior(ZtZ, ZtX, st.active, st.sigma_x, st.sigma_a)
    order = jnp.argsort(-jnp.sum(st.Z, axis=0) * st.active)
    return np.asarray(A[order]), int(jnp.sum(st.active))


def posterior_features_hybrid(X, P, iters, L, K_max, seed):
    smp = build_sampler(
        SamplerSpec(P=P, K_max=K_max, K_tail=8, K_init=4, L=L, seed=seed),
        IBPHypers(), X,
    )
    N = smp.N
    gs, ss = smp.init(jax.random.key(seed))
    for _ in range(iters):
        gs, ss = smp.step(gs, ss)
    Z = ss.Z.reshape(N, -1)
    ZtZ = (Z.T @ Z) * ibm.mask_outer(gs.active)
    ZtX = (Z.T @ smp.Xs.reshape(N, -1)) * gs.active[:, None]
    A, _ = ibm.a_posterior(ZtZ, ZtX, gs.active, gs.sigma_x, gs.sigma_a)
    order = jnp.argsort(-jnp.sum(Z, axis=0) * gs.active)
    return np.asarray(A[order]), int(jnp.sum(gs.active))


def ascii_render(A: np.ndarray, label: str, k: int = 4) -> str:
    """Render the top-k features as 6x6 ASCII blocks side by side."""
    rows = [label]
    imgs = [A[i].reshape(6, 6) for i in range(min(k, A.shape[0]))]
    hi = max(float(np.abs(A[:k]).max()), 1e-6)
    for r in range(6):
        line = []
        for im in imgs:
            line.append("".join(
                "#" if im[r, c] > 0.5 * hi else
                "+" if im[r, c] > 0.25 * hi else "."
                for c in range(6)
            ))
        rows.append("  ".join(line))
    return "\n".join(rows)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--N", type=int, default=300)
    ap.add_argument("--iters", type=int, default=120)
    ap.add_argument("--L", type=int, default=5)
    ap.add_argument("--K-max", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    X, _, A_true = cambridge_data(N=args.N, sigma_n=0.5, seed=args.seed)

    A_c, K_c = posterior_features_collapsed(X, args.iters, args.K_max,
                                            args.seed)
    A_h, K_h = posterior_features_hybrid(X, 5, args.iters, args.L, args.K_max,
                                         args.seed)

    _, sse_c = match_features(A_c[:max(K_c, 4)], A_true)
    _, sse_h = match_features(A_h[:max(K_h, 4)], A_true)

    os.makedirs(ART, exist_ok=True)
    np.save(os.path.join(ART, "fig2_true.npy"), A_true)
    np.save(os.path.join(ART, "fig2_collapsed.npy"), A_c)
    np.save(os.path.join(ART, "fig2_hybrid.npy"), A_h)

    print(ascii_render(A_true, "true features:"))
    print(ascii_render(A_c, f"collapsed (K={K_c}, match SSE={sse_c:.2f}):"))
    print(ascii_render(A_h, f"hybrid P=5 (K={K_h}, match SSE={sse_h:.2f}):"))

    lines = [
        f"fig2__collapsed,0,K={K_c};match_sse={sse_c:.2f}",
        f"fig2__hybrid_P5,0,K={K_h};match_sse={sse_h:.2f}",
    ]
    for ln in lines:
        print(ln)
    return lines


if __name__ == "__main__":
    main()
