"""Paper Fig. 1: joint log P(X, Z) on held-out data over wall-clock time.

Runs the collapsed Gibbs baseline and the hybrid sampler at P in {1, 3, 5}
on the Cambridge synthetic set and writes a (run, iter, time_s, ll_eval,
K, sigma_x) trace to artifacts/fig1.csv.

Paper claims validated here (EXPERIMENTS.md §Fig1):
  * adding processors gives speedup without a big difference in estimate
    quality (traces reach the same ll plateau);
  * even with one processor the hybrid converges faster than the purely
    collapsed sampler (its instantiated-feature sweep is vectorized; only
    the tail is a serial row scan).

Full-size run (paper: N=1000, 1000 iters): ``python -m benchmarks.fig1_convergence
--N 1000 --iters 1000``. The default is scaled down to finish on one CPU core
in a few minutes; the qualitative ordering is identical.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ibp import (
    IBPHypers,
    SamplerSpec,
    build_sampler,
    collapsed_sweep,
    init_state,
)
# per-draw AND ensemble estimators both live in the predictive serving
# subsystem now (DESIGN.md §15): heldout_joint_loglik is the per-draw
# Fig. 1 metric; the post-burn-in SampleBank mixture is the ensemble
# predictive log-likelihood each hybrid run reports at the end
from repro.core.ibp.predict import (
    BankBuilder,
    heldout_joint_loglik,
    predictive_loglik,
)
from repro.data import cambridge_data, train_eval_split

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")


def run_collapsed(X_train, X_eval, iters, K_max, seed, eval_every):
    N, D = X_train.shape
    st = init_state(jax.random.key(seed), N, D, K_max, K_init=1)
    X = jnp.asarray(X_train)
    hyp = IBPHypers()
    # warm up the jit so timing measures sampling, not compilation
    collapsed_sweep(st, X, hyp).Z.block_until_ready()
    trace = []
    t0 = time.time()
    for it in range(iters):
        st = collapsed_sweep(st, X, hyp)
        if (it + 1) % eval_every == 0 or it == iters - 1:
            jax.block_until_ready(st.Z)
            t = time.time() - t0
            # collapsed sampler has no instantiated A: draw it for eval
            from repro.core.ibp import math as ibm
            ZtZ = (st.Z.T @ st.Z) * ibm.mask_outer(st.active)
            ZtX = (st.Z.T @ X) * st.active[:, None]
            A = ibm.a_posterior_draw(
                jax.random.fold_in(st.key, 55), ZtZ, ZtX, st.active,
                st.sigma_x, st.sigma_a,
            )
            m = jnp.sum(st.Z * st.active[None, :], axis=0)
            pi = jnp.clip(m / N, 1e-4, 1 - 1e-4) * st.active
            ll = float(heldout_joint_loglik(
                jnp.asarray(X_eval), A, pi, st.active, st.sigma_x,
                jax.random.fold_in(st.key, 99),
            ))
            trace.append(dict(run="collapsed", iter=it + 1, time_s=t,
                              ll_eval=ll, K=int(st.k_plus),
                              sigma_x=float(st.sigma_x)))
    return trace


def run_hybrid(X_train, X_eval, P, iters, L, K_max, seed, eval_every):
    smp = build_sampler(
        SamplerSpec(P=P, K_max=K_max, K_tail=8, K_init=4, L=L, seed=seed),
        IBPHypers(), X_train,
    )
    gs, ss = smp.init(jax.random.key(seed))
    g, s = smp.step(gs, ss)
    jax.block_until_ready(s.Z)  # warm-up compile
    bank = BankBuilder(K_max)  # post-burn ensemble for the mixture ll
    trace = []
    t0 = time.time()
    for it in range(iters):
        gs, ss = smp.step(gs, ss)
        if (it + 1) % eval_every == 0 or it == iters - 1:
            jax.block_until_ready(ss.Z)
            t = time.time() - t0
            ll = float(heldout_joint_loglik(
                jnp.asarray(X_eval), gs.A, gs.pi, gs.active, gs.sigma_x,
                jax.random.fold_in(gs.key, 99),
            ))
            if (it + 1) > iters // 2:
                bank.add_state(gs, it=it + 1)
            trace.append(dict(run=f"hybrid_P{P}", iter=it + 1, time_s=t,
                              ll_eval=ll, K=int(jnp.sum(gs.active)),
                              sigma_x=float(gs.sigma_x)))
    if len(bank):
        mix = predictive_loglik(bank.build(), jnp.asarray(X_eval),
                                jax.random.key(seed + 77))
        trace[-1]["ll_bank_mix"] = float(jnp.sum(mix))
        trace[-1]["bank_S"] = len(bank)
    return trace


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--N", type=int, default=240)
    ap.add_argument("--iters", type=int, default=120)
    ap.add_argument("--collapsed-iters", type=int, default=0,
                    help="0 -> same as --iters")
    ap.add_argument("--L", type=int, default=5)
    ap.add_argument("--K-max", type=int, default=24)
    ap.add_argument("--P", type=int, nargs="+", default=[1, 3, 5])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--eval-every", type=int, default=10)
    ap.add_argument("--skip-collapsed", action="store_true")
    args = ap.parse_args(argv)

    X, _, _ = cambridge_data(N=args.N, sigma_n=0.5, seed=args.seed)
    X_train, X_eval = train_eval_split(X, eval_frac=0.1, seed=args.seed)

    rows = []
    if not args.skip_collapsed:
        rows += run_collapsed(X_train, X_eval,
                              args.collapsed_iters or args.iters,
                              args.K_max, args.seed, args.eval_every)
        print(f"collapsed: done ({rows[-1]['time_s']:.1f}s, "
              f"ll={rows[-1]['ll_eval']:.1f}, K={rows[-1]['K']})", flush=True)
    for P in args.P:
        tr = run_hybrid(X_train, X_eval, P, args.iters, args.L, args.K_max,
                        args.seed, args.eval_every)
        rows += tr
        print(f"hybrid P={P}: done ({tr[-1]['time_s']:.1f}s, "
              f"ll={tr[-1]['ll_eval']:.1f}, K={tr[-1]['K']})", flush=True)

    os.makedirs(ART, exist_ok=True)
    out = os.path.join(ART, "fig1.csv")
    with open(out, "w") as fh:
        fh.write("run,iter,time_s,ll_eval,K,sigma_x\n")
        for r in rows:
            fh.write(f"{r['run']},{r['iter']},{r['time_s']:.3f},"
                     f"{r['ll_eval']:.2f},{r['K']},{r['sigma_x']:.4f}\n")
    print(f"-> {out}")

    # contract for benchmarks.run: name,us_per_call,derived
    summary = {}
    for r in rows:
        summary[r["run"]] = r  # last record per run wins
    csv_lines = []
    for name, r in summary.items():
        us = r["time_s"] / r["iter"] * 1e6
        derived = f"final_ll={r['ll_eval']:.1f};K={r['K']}"
        if "ll_bank_mix" in r:
            # the §15 ensemble estimator: logsumexp-over-samples mixture
            # predictive ll of the post-burn SampleBank on the eval set
            derived += (f";bank_mix_ll={r['ll_bank_mix']:.1f}"
                        f";bank_S={r['bank_S']}")
        csv_lines.append(f"fig1__{name},{us:.0f},{derived}")
    # the paper's headline: time for the hybrid to pass the collapsed
    # sampler's final ll
    if "collapsed" in summary:
        target = summary["collapsed"]["ll_eval"]
        for name, r in summary.items():
            if name == "collapsed":
                continue
            first = next((x for x in rows if x["run"] == name
                          and x["ll_eval"] >= target), None)
            if first:
                csv_lines.append(
                    f"fig1__{name}__time_to_collapsed_ll,"
                    f"{first['time_s'] * 1e6:.0f},"
                    f"vs_collapsed_s={summary['collapsed']['time_s']:.1f}"
                )
    for line in csv_lines:
        print(line)
    return csv_lines


if __name__ == "__main__":
    main()
