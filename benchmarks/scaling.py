"""Scaling: hybrid-sampler iteration time vs processor count P.

Two measurements (artifacts/scaling.csv):

  * serial-tail amortization on ONE device (vmap driver): the paper's reason
    hybrid scales — the only serial O(N_p) scan is the collapsed tail on p',
    so per-iteration serial work shrinks as 1/P while the uncollapsed sweep
    is a fixed batch of matrix work.
  * shard_map step time on P forced host devices (subprocess, 1..8): proves
    the production collective path runs at any P and measures the sync
    overhead (all host devices share one core, so this is overhead, not
    speedup).
"""
from __future__ import annotations

import argparse
import os
import time

import jax

from benchmarks._hostdev import run_hostdev
from repro.core.ibp import IBPHypers, SamplerSpec, build_sampler
from repro.data import cambridge_data

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")


def time_vmap(N: int, P: int, iters: int, L: int, K_max: int) -> float:
    X, _, _ = cambridge_data(N=N, seed=0)
    s = build_sampler(SamplerSpec(P=P, K_max=K_max, K_tail=8, K_init=4, L=L),
                      IBPHypers(), X)
    gs, st = s.init(jax.random.key(0))
    gs, st = s.step(gs, st)
    jax.block_until_ready(st.Z)  # compile
    t0 = time.time()
    for _ in range(iters):
        gs, st = s.step(gs, st)
    jax.block_until_ready(st.Z)
    return (time.time() - t0) / iters


def time_shardmap(N: int, P: int, iters: int, L: int, K_max: int) -> float:
    """Run in a subprocess with P forced devices; returns s/iter."""
    code = f"""
        import time, jax
        from repro.data import cambridge_data
        from repro.core.ibp import IBPHypers, SamplerSpec, build_sampler
        X, _, _ = cambridge_data(N={N}, seed=0)
        spec = SamplerSpec(P={P}, K_max={K_max}, K_tail=8, K_init=4, L={L},
                           data="shardmap")
        s = build_sampler(spec, IBPHypers(), X)
        gs, st = s.init(jax.random.key(0))
        gs, st = s.step(gs, st)   # compile
        jax.block_until_ready(st[0])
        t0 = time.time()
        for _ in range({iters}):
            gs, st = s.step(gs, st)
        jax.block_until_ready(st[0])
        print((time.time() - t0) / {iters})
    """
    out = run_hostdev(code, P)
    return float(out.stdout.strip().splitlines()[-1])


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--N", type=int, default=240)
    ap.add_argument("--iters", type=int, default=10)
    ap.add_argument("--L", type=int, default=5)
    ap.add_argument("--K-max", type=int, default=24)
    ap.add_argument("--P", type=int, nargs="+", default=[1, 2, 4, 8])
    ap.add_argument("--skip-shardmap", action="store_true")
    args = ap.parse_args(argv)

    rows, lines = [], []
    for P in args.P:
        s = time_vmap(args.N, P, args.iters, args.L, args.K_max)
        rows.append(("vmap", P, s))
        lines.append(f"scaling__vmap_P{P},{s * 1e6:.0f},N={args.N};L={args.L}")
        print(lines[-1], flush=True)
    if not args.skip_shardmap:
        for P in args.P:
            s = time_shardmap(args.N, P, args.iters, args.L, args.K_max)
            rows.append(("shard_map", P, s))
            lines.append(
                f"scaling__shardmap_P{P},{s * 1e6:.0f},N={args.N};L={args.L}"
            )
            print(lines[-1], flush=True)

    os.makedirs(ART, exist_ok=True)
    with open(os.path.join(ART, "scaling.csv"), "w") as fh:
        fh.write("driver,P,s_per_iter\n")
        for d, P, s in rows:
            fh.write(f"{d},{P},{s:.4f}\n")
    print(f"-> {os.path.join(ART, 'scaling.csv')}")
    return lines


if __name__ == "__main__":
    main()
