"""Scaling: hybrid-sampler iteration time vs processor count P.

Two measurements (artifacts/scaling.csv):

  * serial-tail amortization on ONE device (vmap driver): the paper's reason
    hybrid scales — the only serial O(N_p) scan is the collapsed tail on p',
    so per-iteration serial work shrinks as 1/P while the uncollapsed sweep
    is a fixed batch of matrix work.
  * shard_map step time on P forced host devices (subprocess, 1..8): proves
    the production collective path runs at any P and measures the sync
    overhead (all host devices share one core, so this is overhead, not
    speedup).
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
import textwrap
import time

import jax
import jax.numpy as jnp

from repro.core.ibp import IBPHypers, hybrid_iteration_vmap, init_hybrid
from repro.data import cambridge_data, shard_rows

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")
SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def time_vmap(N: int, P: int, iters: int, L: int, K_max: int) -> float:
    X, _, _ = cambridge_data(N=N, seed=0)
    Xs = jnp.asarray(shard_rows(X, P))
    hyp = IBPHypers()
    gs, ss = init_hybrid(jax.random.key(0), Xs, K_max, K_tail=8, K_init=4)
    gs, ss = hybrid_iteration_vmap(Xs, gs, ss, hyp, L=L, N_global=N)
    jax.block_until_ready(ss.Z)  # compile
    t0 = time.time()
    for _ in range(iters):
        gs, ss = hybrid_iteration_vmap(Xs, gs, ss, hyp, L=L, N_global=N)
    jax.block_until_ready(ss.Z)
    return (time.time() - t0) / iters


def time_shardmap(N: int, P: int, iters: int, L: int, K_max: int) -> float:
    """Run in a subprocess with P forced devices; returns s/iter."""
    code = textwrap.dedent(f"""
        import time, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.data import cambridge_data, shard_rows
        from repro.core.ibp import IBPHypers, init_hybrid, \\
            make_hybrid_iteration_shardmap
        X, _, _ = cambridge_data(N={N}, seed=0)
        Pn = {P}
        Xs = jnp.asarray(shard_rows(X, Pn))
        gs, ss = init_hybrid(jax.random.key(0), Xs, {K_max}, K_tail=8,
                             K_init=4)
        from repro.compat import make_mesh, set_mesh, AxisType
        mesh = make_mesh((Pn,), ('data',), axis_types=(AxisType.Auto,))
        step = make_hybrid_iteration_shardmap(mesh, ('data',), IBPHypers(),
                                              L={L}, N_global={N})
        with set_mesh(mesh):
            sh = NamedSharding(mesh, P('data'))
            Xf = jax.device_put(Xs.reshape(-1, Xs.shape[-1]), sh)
            Zf = jax.device_put(ss.Z.reshape(-1, {K_max}), sh)
            Zt = jax.device_put(ss.Z_tail.reshape(-1, 8), sh)
            ta = jax.device_put(ss.tail_active, sh)
            gs, Zf, Zt, ta = step(Xf, gs, Zf, Zt, ta)   # compile
            jax.block_until_ready(Zf)
            t0 = time.time()
            for _ in range({iters}):
                gs, Zf, Zt, ta = step(Xf, gs, Zf, Zt, ta)
            jax.block_until_ready(Zf)
        print((time.time() - t0) / {iters})
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={P}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=900)
    if out.returncode != 0:
        raise RuntimeError(out.stderr[-2000:])
    return float(out.stdout.strip().splitlines()[-1])


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--N", type=int, default=240)
    ap.add_argument("--iters", type=int, default=10)
    ap.add_argument("--L", type=int, default=5)
    ap.add_argument("--K-max", type=int, default=24)
    ap.add_argument("--P", type=int, nargs="+", default=[1, 2, 4, 8])
    ap.add_argument("--skip-shardmap", action="store_true")
    args = ap.parse_args(argv)

    rows, lines = [], []
    for P in args.P:
        s = time_vmap(args.N, P, args.iters, args.L, args.K_max)
        rows.append(("vmap", P, s))
        lines.append(f"scaling__vmap_P{P},{s * 1e6:.0f},N={args.N};L={args.L}")
        print(lines[-1], flush=True)
    if not args.skip_shardmap:
        for P in args.P:
            s = time_shardmap(args.N, P, args.iters, args.L, args.K_max)
            rows.append(("shard_map", P, s))
            lines.append(
                f"scaling__shardmap_P{P},{s * 1e6:.0f},N={args.N};L={args.L}"
            )
            print(lines[-1], flush=True)

    os.makedirs(ART, exist_ok=True)
    with open(os.path.join(ART, "scaling.csv"), "w") as fh:
        fh.write("driver,P,s_per_iter\n")
        for d, P, s in rows:
            fh.write(f"{d},{P},{s:.4f}\n")
    print(f"-> {os.path.join(ART, 'scaling.csv')}")
    return lines


if __name__ == "__main__":
    main()
