"""Diagnostic: rank collectives in a cell's partitioned HLO by bytes.

    PYTHONPATH=src python -m benchmarks.hlo_collectives --arch deepseek-v2-236b \
        --shape train_4k [--layers 1]

Lowers the cell at a reduced UNROLLED depth (so every per-layer collective is
visible and attributable) and prints per-op byte totals grouped by (op kind,
result shape, source op_name metadata) — the profile §Perf iterates on.
"""
from __future__ import annotations

import argparse
import re
from collections import defaultdict

from repro.launch import dryrun


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="pod1")
    ap.add_argument("--layers", type=int, default=1)
    ap.add_argument("--top", type=int, default=25)
    args = ap.parse_args()

    import dataclasses
    import jax
    from repro.configs import ALL_SHAPES, get_config
    from repro import compat
    from repro.launch.specs import abstract_model, param_bytes
    from repro.parallel.mesh import make_production_mesh

    shape = next(s for s in ALL_SHAPES if s.name == args.shape)
    cfg = get_config(args.arch)
    pstruct, _ = abstract_model(cfg, serve=shape.mode != "train")
    full_pbytes = param_bytes(pstruct, 2)
    sub = {"n_layers": args.layers, "unroll_layers": True}
    if cfg.family == "encdec":
        sub["n_enc_layers"] = args.layers
    cfg_l = dataclasses.replace(cfg, **sub)
    mesh = make_production_mesh(multi_pod=(args.mesh == "pod2"))
    with compat.set_mesh(mesh):
        fn, fargs = dryrun.build_step(cfg_l, shape, mesh,
                                      force_param_bytes=full_pbytes)
        hlo = fn.lower(*fargs).compile().as_text()

    groups: dict[tuple, list] = defaultdict(lambda: [0, 0])
    for line in hlo.splitlines():
        ls = line.strip()
        if "=" not in ls:
            continue
        for op in dryrun.COLLECTIVE_OPS:
            m = re.search(rf"= (.*?) {op}(?:-start)?\(", ls)
            if not m:
                continue
            nbytes = dryrun._shape_bytes(m.group(1))
            mm = re.search(r'op_name="([^"]*)"', ls)
            src = mm.group(1) if mm else "?"
            src = re.sub(r"/while/body", "", src)[:110]
            key = (op, m.group(1)[:48], src)
            groups[key][0] += nbytes
            groups[key][1] += 1
            break

    rows = sorted(groups.items(), key=lambda kv: -kv[1][0])
    total = sum(v[0] for v in groups.values())
    print(f"{args.arch} x {args.shape} @ {args.mesh}, {args.layers} layer(s) "
          f"unrolled — total collective result-bytes/dev: {total / 2**30:.2f} GiB")
    print(f"{'GiB':>8} {'n':>4}  kind             shape / source")
    for (op, shp, src), (b, n) in rows[: args.top]:
        print(f"{b / 2**30:8.3f} {n:4d}  {op:16s} {shp}")
        print(f"{'':14}{src}")


if __name__ == "__main__":
    main()
