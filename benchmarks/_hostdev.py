"""Shared forced-host-devices subprocess harness for benchmarks.

Several benchmarks need a REAL multi-device mesh on a CPU-only box
(shardmap / mesh drivers). JAX fixes the device count at backend init,
so the only clean way is a subprocess with
``--xla_force_host_platform_device_count`` in XLA_FLAGS — a pattern that
used to be copy-pasted between benchmarks/collapsed.py and
benchmarks/scaling.py (ROADMAP follow-up). All host devices share one
core, so these runs measure collective/dispatch OVERHEAD, not speedup.

``run_hostdev`` returns raw stdout; ``run_hostdev_json`` extracts a
``BENCH_JSON:{...}`` payload printed by the snippet (None on failure,
with stderr forwarded — benchmarks degrade gracefully, they don't
crash the harness).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
JSON_TAG = "BENCH_JSON:"


def run_hostdev(code: str, n_devices: int, *, timeout: int = 900,
                check: bool = True) -> subprocess.CompletedProcess:
    """Run ``code`` in a subprocess with ``n_devices`` forced host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count="
                          f"{n_devices}")
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env["JAX_PLATFORMS"] = "cpu"
    res = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=timeout,
    )
    if check and res.returncode != 0:
        raise RuntimeError(res.stderr[-2000:])
    return res


def run_hostdev_json(code: str, n_devices: int, *,
                     timeout: int = 900) -> dict | None:
    """Run ``code`` and parse the last ``BENCH_JSON:{...}`` stdout line."""
    try:
        res = run_hostdev(code, n_devices, timeout=timeout, check=False)
    except subprocess.TimeoutExpired:
        print("hostdev subprocess timed out", file=sys.stderr)
        return None
    payload = None
    for line in res.stdout.splitlines():
        if line.startswith(JSON_TAG):
            payload = json.loads(line[len(JSON_TAG):])
    if payload is None:
        print(res.stdout[-2000:], res.stderr[-2000:], file=sys.stderr)
    return payload
