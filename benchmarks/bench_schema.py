"""Fail-closed schema lint for the BENCH_*.json perf trajectory.

The durable trajectory has two writers (`benchmarks/run.py` and
`repro.launch.serve_ibp`) merging sections into the same date-keyed
file, so a malformed section silently poisons the history consumers
(the CI gates, the roofline table, anyone diffing trajectories). This
lint closes that hole and gates `--smoke`:

* every `BENCH_*.json` at the repo root is linted — zero files found
  is itself a failure (the trajectory must exist);
* every section present in a file must be REGISTERED in ``SECTIONS``
  below with its required row keys — an unknown section fails (new
  benchmarks must declare their schema here to land);
* required keys must be present with the right type, numeric metrics
  must be finite, and throughput/latency/speedup metrics must be
  positive. Extra keys are allowed (forward-compatible).

It also hosts the unified-core no-regression gate
(``unpacked_core_regression``): the occupancy sweep's
``k_live_buckets="off"`` timing now runs `_packed_scan` pinned to the
top bucket (DESIGN.md §12), while the committed trajectory rows were
measured with the pre-unification dedicated unpacked carry — so
comparing current unpacked rows/s against the recorded row at the same
(N, D, K_max, K_plus_target) proves deleting `_row_step_fast` cost no
throughput. The margin is generous (shared-CI noise); a structural
regression (the top bucket paying packing overhead) would show as ~2x.

CLI: ``python -m benchmarks.bench_schema`` exits 1 on any lint error.
"""
from __future__ import annotations

import glob
import json
import math
import os
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_NUM = (int, float)

# top-level run metadata every BENCH file must carry
TOP_LEVEL = {"date": str, "mode": str, "jax_backend": str,
             "device_count": int}

# section name -> shape spec. kind:
#   "rows"  — the section IS a list of row dicts
#   "table" — dict with scalar meta keys + a "results" row list
#   "flat"  — one flat dict of required keys
SECTIONS: dict[str, dict] = {
    "kernels": dict(
        kind="rows",
        row={"name": str, "us": _NUM, "allclose": bool,
             "arith_intensity": _NUM, "shape": dict},
    ),
    "collapsed_sweep": dict(
        kind="table",
        meta={"N": int, "D": int, "refresh_every": int},
        row={"K_max": int, "K_plus": int,
             "ref_rows_per_s": _NUM, "fast_rows_per_s": _NUM,
             "ref_ms_per_sweep": _NUM, "fast_ms_per_sweep": _NUM,
             "speedup": _NUM},
    ),
    "occupancy_sweep": dict(
        kind="table",
        meta={"N": int, "D": int, "refresh_every": int},
        row={"K_max": int, "K_plus_target": int, "K_plus": int,
             "unpacked_rows_per_s": _NUM, "packed_rows_per_s": _NUM,
             "unpacked_ms_per_sweep": _NUM, "packed_ms_per_sweep": _NUM,
             "packed_speedup": _NUM},
    ),
    "uncollapsed_sweep": dict(
        kind="table",
        meta={"D": int, "K": int},
        row={"backend": str, "rows": int, "rows_per_s": _NUM,
             "interpreted": bool},
    ),
    "hybrid_sync": dict(
        kind="flat",
        keys={"staged_s": _NUM, "fused_s": _NUM, "P": int, "N": int,
              "K_max": int, "L": int},
    ),
    "predict_serving": dict(
        kind="table",
        meta={"config": dict},
        row={"S": int, "B": int, "K": int, "D": int,
             "batched_us": _NUM, "speedup": _NUM,
             "rows_per_s_batched": _NUM},
        extra_row_lists={"ops": {"op": str, "S": int, "K": int,
                                 "rows_per_s": _NUM, "us_per_call": _NUM}},
    ),
    "serving_loop": dict(
        kind="rows",
        row={"op": str, "S": int, "K": int, "D": int, "batch": int,
             "rows": int, "rows_per_s": _NUM,
             "latency_p50_us": _NUM, "latency_p95_us": _NUM},
    ),
}

# numeric metrics with these suffixes must be strictly positive
_POSITIVE_SUFFIXES = ("rows_per_s", "_ms_per_sweep", "speedup", "_us",
                      "_s", "us_per_call")


def _check_type(val, typ) -> bool:
    if typ is int:
        return isinstance(val, int) and not isinstance(val, bool)
    if typ is bool:
        return isinstance(val, bool)
    if typ == _NUM:
        return isinstance(val, _NUM) and not isinstance(val, bool)
    return isinstance(val, typ)


def _check_keys(obj, spec: dict, where: str) -> list[str]:
    errs = []
    if not isinstance(obj, dict):
        return [f"{where}: expected an object, got {type(obj).__name__}"]
    for key, typ in spec.items():
        if key not in obj:
            errs.append(f"{where}: missing required key '{key}'")
            continue
        val = obj[key]
        if not _check_type(val, typ):
            errs.append(f"{where}.{key}: expected {typ}, got "
                        f"{type(val).__name__} ({val!r})")
            continue
        if _check_type(val, _NUM) and typ == _NUM:
            if not math.isfinite(val):
                errs.append(f"{where}.{key}: non-finite metric ({val!r})")
            elif val <= 0 and key.endswith(_POSITIVE_SUFFIXES):
                errs.append(f"{where}.{key}: non-positive metric ({val!r})")
    return errs


def _check_rows(rows, row_spec: dict, where: str) -> list[str]:
    if not isinstance(rows, list):
        return [f"{where}: expected a row list, got {type(rows).__name__}"]
    if not rows:
        return [f"{where}: empty row list (a vacuous section cannot gate)"]
    errs = []
    for i, row in enumerate(rows):
        errs += _check_keys(row, row_spec, f"{where}[{i}]")
    return errs


def lint_payload(payload: dict, where: str = "BENCH") -> list[str]:
    """Lint one BENCH payload dict. Returns a list of error strings."""
    errs = _check_keys(payload, TOP_LEVEL, where)
    known = set(TOP_LEVEL) | set(SECTIONS)
    for name in payload:
        if name not in known:
            errs.append(f"{where}.{name}: unregistered section — declare "
                        f"its schema in benchmarks/bench_schema.py")
    for name, spec in SECTIONS.items():
        if name not in payload:
            continue  # sections are optional (two writers, partial runs)
        sec = payload[name]
        loc = f"{where}.{name}"
        if spec["kind"] == "rows":
            errs += _check_rows(sec, spec["row"], loc)
        elif spec["kind"] == "flat":
            errs += _check_keys(sec, spec["keys"], loc)
        else:  # table
            errs += _check_keys(sec, spec["meta"], loc)
            if isinstance(sec, dict):
                errs += _check_rows(sec.get("results"), spec["row"],
                                    f"{loc}.results")
                for lname, lspec in spec.get("extra_row_lists",
                                             {}).items():
                    if lname in sec:
                        errs += _check_rows(sec[lname], lspec,
                                            f"{loc}.{lname}")
    return errs


def bench_files(root: str = REPO_ROOT) -> list[str]:
    return sorted(glob.glob(os.path.join(root, "BENCH_*.json")))


def lint_repo(root: str = REPO_ROOT) -> list[str]:
    """Lint every BENCH_*.json at the repo root, fail-closed."""
    files = bench_files(root)
    if not files:
        return [f"no BENCH_*.json found under {root} — the perf "
                f"trajectory must exist (fail closed)"]
    errs = []
    for path in files:
        name = os.path.basename(path)
        try:
            with open(path) as fh:
                payload = json.load(fh)
        except (OSError, ValueError) as exc:
            errs.append(f"{name}: unreadable ({exc})")
            continue
        errs += lint_payload(payload, where=name)
    return errs


def unpacked_core_regression(current_occ: dict, root: str = REPO_ROOT,
                             min_ratio: float = 0.6,
                             skip_date: str | None = None) -> list[str]:
    """Unified-core no-regression gate (DESIGN.md §12).

    ``current_occ`` is this run's ``occupancy_sweep`` section, whose
    ``unpacked_rows_per_s`` was measured on `_packed_scan` pinned to
    the top bucket; the committed trajectory's matching rows were
    measured with the deleted dedicated unpacked carry. Absolute
    rows/s do not transfer across runs on shared CI (a loaded box
    slows everything 2-3x), so the gate compares the LOAD-INVARIANT
    unpacked/packed throughput ratio — both sides of each row come
    from the same run with interleaved repeats, so machine speed
    cancels, and a regression specific to the top-bucket degenerate
    mode (the deleted-path replacement) shows as that ratio dropping
    below ``min_ratio`` of the recorded ratio. A slowdown uniform
    across both modes is the companion fast>=2x-ref same-run gate's
    job. Fails closed when there is no comparable recorded row at the
    same (N, D, K_max, K_plus_target). ``skip_date`` excludes the
    file this run is about to merge into (today's), which may hold
    its own fresh numbers rather than a pre-unification record.
    """
    cur_rows = (current_occ or {}).get("results") or []
    if not cur_rows:
        return ["unified-core gate: current run produced no "
                "occupancy_sweep rows (fail closed)"]
    recorded = None
    for path in reversed(bench_files(root)):
        if skip_date and os.path.basename(path) == f"BENCH_{skip_date}.json":
            continue
        try:
            with open(path) as fh:
                payload = json.load(fh)
        except (OSError, ValueError):
            continue
        occ = payload.get("occupancy_sweep")
        if occ and occ.get("results"):
            recorded = (os.path.basename(path), occ)
            break
    if recorded is None:
        return ["unified-core gate: no recorded occupancy_sweep in any "
                "BENCH_*.json to compare against (fail closed)"]
    rec_name, rec_occ = recorded
    if (current_occ.get("N") != rec_occ.get("N")
            or current_occ.get("D") != rec_occ.get("D")):
        return [f"unified-core gate: current sweep sizes "
                f"(N={current_occ.get('N')}, D={current_occ.get('D')}) do "
                f"not match {rec_name} (N={rec_occ.get('N')}, "
                f"D={rec_occ.get('D')}) — nothing comparable (fail closed)"]
    errs = []
    compared = 0
    for cur in cur_rows:
        match = [r for r in rec_occ["results"]
                 if r.get("K_max") == cur.get("K_max")
                 and r.get("K_plus_target") == cur.get("K_plus_target")]
        if not match:
            continue
        compared += 1
        rec = match[0]
        rec_frac = rec["unpacked_rows_per_s"] / rec["packed_rows_per_s"]
        cur_frac = cur["unpacked_rows_per_s"] / cur["packed_rows_per_s"]
        if cur_frac < min_ratio * rec_frac:
            errs.append(
                f"unified-core gate: top-bucket unpacked sweep at "
                f"K_max={cur['K_max']}/K_plus={cur['K_plus_target']} runs "
                f"at {cur_frac:.2f}x its same-run packed throughput vs "
                f"{rec_frac:.2f}x recorded in {rec_name} "
                f"(< {min_ratio:.2f}x of the record — the unified core "
                f"regressed vs the deleted unpacked carry)")
    if compared == 0:
        errs.append(
            f"unified-core gate: no row of {rec_name} matches the "
            f"current sweep's (N, D, K_max, K_plus_target) — the gate "
            f"would be vacuous (fail closed)")
    return errs


def main(argv=None) -> int:
    errs = lint_repo()
    for e in errs:
        print(f"BENCH lint: {e}", file=sys.stderr)
    if not errs:
        print(f"BENCH lint: {len(bench_files())} file(s) clean")
    return 1 if errs else 0


if __name__ == "__main__":
    raise SystemExit(main())
