"""Bank-scoring throughput: the (S × B)-batched jitted scorer vs the
naive per-sample loop (DESIGN.md §15).

What is measured, per (S, B, K) grid point:

* ``batched`` — ONE ``predict.predictive_loglik`` dispatch scoring the
  whole B-row workload against all S bank samples (the serving
  subsystem's path: microbatch coalescing + ensemble batching).
* ``naive_request`` — the serving counterfactual THE SUBSYSTEM
  REPLACES: the workload arrives as ``B / req_rows`` requests, each
  scored by a python loop over the S samples dispatching one jitted
  per-sample scorer per (sample, request) — pre-§15 ensemble scoring
  (S sequential ``heldout_joint_loglik``-style calls) at request
  granularity, with no coalescing. This is the headline ``speedup``.
* ``naive_monolithic`` — the same per-sample loop given the whole
  B-row workload as one batch (generous to the baseline: it assumes a
  batcher already exists). Reported alongside for transparency; on
  few-core CPUs both sides of this comparison are flop-bound, so it
  mostly measures BLAS shape efficiency, not the subsystem.

Encode and impute are spot-checked at the required point so all three
serving ops have a durable rows/s trajectory in ``BENCH_<date>.json``.

Full run: ``python -m benchmarks.predict``; the ``benchmarks.run``
harness calls this with CPU-sized grids and gates the smoke on the
required point (S=32, B=256, K=64).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

REQUIRED = (32, 256, 64)  # (S, B, K) — the gated BENCH point


def _t(fn, reps: int) -> float:
    import jax

    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return min(ts)


def make_bank(S: int, K: int, D: int, seed: int = 0):
    """Synthetic full-occupancy bank at feature width K (bucket == K)."""
    from repro.core.ibp.predict import BankBuilder

    rng = np.random.default_rng(seed)
    bb = BankBuilder(K_max=K)
    for s in range(S):
        bb.add(rng.normal(size=(K, D)).astype(np.float32) * 0.5,
               rng.uniform(0.1, 0.9, K), np.ones(K),
               0.7, 1.0, 2.0, chain=0, it=s)
    return bb.build()


def bench_point(S: int, B: int, K: int, D: int, n_sweeps: int,
                req_rows: int, reps: int, seed: int = 0) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.core.ibp import predict

    bank = make_bank(S, K, D, seed)
    rng = np.random.default_rng(seed + 1)
    X = jnp.asarray(rng.normal(size=(B, D)), jnp.float32)
    key = jax.random.key(seed)
    req = min(req_rows, B)

    def batched():
        return predict.predictive_loglik(bank, X, key, n_sweeps=n_sweeps)

    def naive_request():
        outs = []
        for i in range(0, B, req):
            outs.append(predict.predictive_loglik_naive(
                bank, X[i:i + req], key, n_sweeps=n_sweeps))
        return jnp.concatenate(outs)

    def naive_monolithic():
        return predict.predictive_loglik_naive(bank, X, key,
                                               n_sweeps=n_sweeps)

    # warm every jit cache entry first: steady-state serving throughput
    for f in (batched, naive_request, naive_monolithic):
        jax.block_until_ready(f())
    t_b = _t(batched, reps)
    t_r = _t(naive_request, max(1, reps - 1))
    t_m = _t(naive_monolithic, max(1, reps - 1))
    return {
        "S": S, "B": B, "K": K, "D": D,
        "n_sweeps": n_sweeps, "req_rows": req,
        "batched_us": t_b * 1e6,
        "naive_request_us": t_r * 1e6,
        "naive_monolithic_us": t_m * 1e6,
        "rows_per_s_batched": B / t_b,
        "rows_per_s_naive_request": B / t_r,
        "speedup": t_r / t_b,                # vs the serving counterfactual
        "speedup_vs_monolithic": t_m / t_b,  # generous-baseline view
    }


def bench_ops_point(S: int, B: int, K: int, D: int, reps: int,
                    seed: int = 0) -> list[dict]:
    import jax
    import jax.numpy as jnp

    from repro.core.ibp import predict

    bank = make_bank(S, K, D, seed)
    rng = np.random.default_rng(seed + 2)
    X = jnp.asarray(rng.normal(size=(B, D)), jnp.float32)
    mask = jnp.asarray(rng.random((B, D)) > 0.25, jnp.float32)
    key = jax.random.key(seed)
    out = []
    for op, fn in (
        ("encode", lambda: predict.encode(bank, X, key)),
        ("impute", lambda: predict.impute(bank, X, mask, key)),
        ("anomaly", lambda: predict.anomaly_score(bank, X, key)),
    ):
        jax.block_until_ready(fn())
        t = _t(fn, reps)
        out.append({"op": op, "S": S, "B": B, "K": K, "D": D,
                    "us_per_call": t * 1e6, "rows_per_s": B / t})
    return out


def main(argv=None) -> tuple[list[str], dict]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--Ss", type=int, nargs="+", default=[8, 32])
    ap.add_argument("--Bs", type=int, nargs="+", default=[64, 256])
    ap.add_argument("--Ks", type=int, nargs="+", default=[16, 64])
    ap.add_argument("--D", type=int, default=64)
    ap.add_argument("--n-sweeps", type=int, default=3)
    ap.add_argument("--req-rows", type=int, default=8,
                    help="request size of the naive request-granularity "
                         "baseline")
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--required-only", action="store_true",
                    help="only the gated (S=32, B=256, K=64) point "
                         "(CI smoke)")
    args = ap.parse_args(argv)

    grid = ([REQUIRED] if args.required_only else
            sorted({(S, B, K) for S in args.Ss for B in args.Bs
                    for K in args.Ks} | {REQUIRED}))
    results, csv = [], []
    for S, B, K in grid:
        r = bench_point(S, B, K, args.D, args.n_sweeps, args.req_rows,
                        args.reps)
        results.append(r)
        print(f"S={S:3d} B={B:4d} K={K:3d}: batched "
              f"{r['batched_us']/1e3:7.1f}ms "
              f"({r['rows_per_s_batched']:6.0f} rows/s)  naive/request "
              f"{r['naive_request_us']/1e3:7.1f}ms -> {r['speedup']:.1f}x "
              f"(monolithic {r['speedup_vs_monolithic']:.2f}x)", flush=True)
        csv.append(
            f"predict__loglik_S{S}_B{B}_K{K},{r['batched_us']:.0f},"
            f"speedup={r['speedup']:.2f};rows_per_s="
            f"{r['rows_per_s_batched']:.0f}"
        )
    ops = bench_ops_point(*REQUIRED, args.D, args.reps)
    for r in ops:
        print(f"op={r['op']:8s} S={r['S']} B={r['B']} K={r['K']}: "
              f"{r['us_per_call']/1e3:7.1f}ms "
              f"({r['rows_per_s']:6.0f} rows/s)", flush=True)
        csv.append(f"predict__{r['op']}_S{r['S']}_B{r['B']}_K{r['K']},"
                   f"{r['us_per_call']:.0f},"
                   f"rows_per_s={r['rows_per_s']:.0f}")
    payload = {
        "predict_serving": {
            "config": {"D": args.D, "n_sweeps": args.n_sweeps,
                       "req_rows": args.req_rows,
                       "naive": "per-sample loop at request granularity "
                                "(pre-§15 ensemble scoring, no "
                                "coalescing); *_monolithic = same loop "
                                "fed the whole batch"},
            "results": results,
            "ops": ops,
        }
    }
    return csv, payload


if __name__ == "__main__":
    lines, _ = main()
    print("name,us_per_call,derived")
    for l in lines:
        print(l)
