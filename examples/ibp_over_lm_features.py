"""Compose the paper's technique with the LM substrate: IBP feature
discovery over hidden representations emitted by any of the ten assigned
architectures (DESIGN.md §5 — the technique is observation-parallel, so it
runs on anything that produces an N x D real matrix, sharing the same mesh
and data axis as LM data parallelism).

Here: embed token windows with a smoke-config backbone, mean-pool the final
hidden states, then run hybrid parallel MCMC on those pooled vectors.

    PYTHONPATH=src python examples/ibp_over_lm_features.py [--arch smollm-135m]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.ibp import IBPHypers, SamplerSpec, build_sampler
from repro.data.synthetic_lm import SyntheticLM
from repro.models import init_model, model_apply

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="smollm-135m")
ap.add_argument("--N", type=int, default=128, help="observations (windows)")
ap.add_argument("--seq", type=int, default=32)
ap.add_argument("--P", type=int, default=4)
ap.add_argument("--iters", type=int, default=40)
args = ap.parse_args()

# 1. backbone (reduced config of the chosen family) embeds token windows
cfg = get_config(args.arch, smoke=True)
params, _ = init_model(jax.random.key(0), cfg)
data = SyntheticLM(vocab=cfg.vocab, seq_len=args.seq,
                   global_batch=args.N, seed=3)
tokens = jnp.asarray(data.batch(step=1)["tokens"])
print(f"backbone {cfg.name}: embedding {args.N} windows of {args.seq} tokens")


@jax.jit
def embed(tokens):
    batch = {"tokens": tokens}
    if cfg.family == "encdec":
        batch["frames"] = jnp.zeros((tokens.shape[0], cfg.enc_seq,
                                     cfg.d_model))
    logits, _, _ = model_apply(params, batch, cfg, mode="train")
    return logits.mean(axis=1)  # (N, V) pooled; use logits as features


feats = embed(tokens)
# standardize + project to a modest D for the sampler
feats = (feats - feats.mean(0)) / (feats.std(0) + 1e-6)
D = min(64, feats.shape[1])
key = jax.random.key(7)
proj = jax.random.normal(key, (feats.shape[1], D)) / jnp.sqrt(feats.shape[1])
X = feats @ proj
print(f"pooled features: {X.shape}")

# 2. the paper's sampler on the pooled representations, sharded over P
spec = SamplerSpec(P=args.P, K_max=16, K_tail=6, K_init=2, L=3)
sampler = build_sampler(spec, IBPHypers(), jax.device_get(X))
gs, ss = sampler.init(jax.random.key(1))
for it in range(args.iters):
    gs, ss = sampler.step(gs, ss)

K = int(gs.active.sum())
print(f"IBP over {cfg.name} representations: K+ = {K} latent features, "
      f"alpha = {float(gs.alpha):.2f}, sigma_x = {float(gs.sigma_x):.3f}")
assert K >= 1
print("OK")
