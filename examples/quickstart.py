"""Quickstart: discover latent features in the Cambridge data with the
paper's hybrid parallel MCMC, in ~30 seconds on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core.ibp import IBPHypers, SamplerSpec, build_sampler
from repro.core.ibp.diagnostics import train_joint_loglik
from repro.data import cambridge_data

# 1. data: X = Z_true @ A_true + noise, four 6x6 base images (N x 36)
N, P = 200, 4
X, Z_true, A_true = cambridge_data(N=N, sigma_n=0.5, seed=0)

# 2. one spec holds every knob: P "processors" (the paper's data layout,
#    here simulated with data="vmap" — see parallel_ibp.py for a real
#    mesh), feature capacities, sub-iteration count L
spec = SamplerSpec(P=P, K_max=16, K_tail=6, K_init=3, L=5)
sampler = build_sampler(spec, IBPHypers(), X)

# 3. init + run the hybrid sampler: uncollapsed sweeps on instantiated
#    features everywhere, collapsed tail births on one rotating shard p'
gs, ss = sampler.init(jax.random.key(0))
for it in range(60):
    gs, ss = sampler.step(gs, ss)
    if (it + 1) % 20 == 0:
        Z = ss.Z.reshape(N, -1)
        ll = train_joint_loglik(jnp.asarray(X), Z, gs.A, gs.pi, gs.active,
                                gs.sigma_x)
        print(f"iter {it + 1:3d}: K+ = {int(gs.active.sum())}, "
              f"alpha = {float(gs.alpha):.2f}, "
              f"sigma_x = {float(gs.sigma_x):.3f}, "
              f"log P(X,Z) = {float(ll):.1f}")

K = int(gs.active.sum())
print(f"\nrecovered {K} features (truth: 4). First feature as 6x6:")
A0 = gs.A[jnp.argmax(jnp.sum(ss.Z.reshape(N, -1), axis=0) * gs.active)]
for row in jnp.round(A0.reshape(6, 6), 1).tolist():
    print("  " + " ".join(f"{v:+.1f}" for v in row))
assert 3 <= K <= 8, "sampler should find ~4 features"
print("OK")
