"""Quickstart: discover latent features in the Cambridge data with the
paper's hybrid parallel MCMC, in ~30 seconds on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core.ibp import IBPHypers, SamplerSpec, build_sampler, predict
from repro.core.ibp.diagnostics import train_joint_loglik
from repro.data import cambridge_data

# 1. data: X = Z_true @ A_true + noise, four 6x6 base images (N x 36)
N, P = 200, 4
X, Z_true, A_true = cambridge_data(N=N, sigma_n=0.5, seed=0)

# 2. one spec holds every knob: P "processors" (the paper's data layout,
#    here simulated with data="vmap" — see parallel_ibp.py for a real
#    mesh), feature capacities, sub-iteration count L
spec = SamplerSpec(P=P, K_max=16, K_tail=6, K_init=3, L=5)
sampler = build_sampler(spec, IBPHypers(), X)

# 3. init + run the hybrid sampler: uncollapsed sweeps on instantiated
#    features everywhere, collapsed tail births on one rotating shard p'.
#    Past burn-in, harvest posterior samples into a SampleBank — the
#    compact ensemble the predictive serving ops run on (DESIGN.md §15)
gs, ss = sampler.init(jax.random.key(0))
bank_builder = predict.BankBuilder(spec.K_max)
for it in range(60):
    gs, ss = sampler.step(gs, ss)
    if (it + 1) > 30 and (it + 1) % 5 == 0:
        bank_builder.add_state(gs, it=it + 1)
    if (it + 1) % 20 == 0:
        Z = ss.Z.reshape(N, -1)
        ll = train_joint_loglik(jnp.asarray(X), Z, gs.A, gs.pi, gs.active,
                                gs.sigma_x)
        print(f"iter {it + 1:3d}: K+ = {int(gs.active.sum())}, "
              f"alpha = {float(gs.alpha):.2f}, "
              f"sigma_x = {float(gs.sigma_x):.3f}, "
              f"log P(X,Z) = {float(ll):.1f}")

K = int(gs.active.sum())
print(f"\nrecovered {K} features (truth: 4). First feature as 6x6:")
A0 = gs.A[jnp.argmax(jnp.sum(ss.Z.reshape(N, -1), axis=0) * gs.active)]
for row in jnp.round(A0.reshape(6, 6), 1).tolist():
    print("  " + " ".join(f"{v:+.1f}" for v in row))
assert 3 <= K <= 8, "sampler should find ~4 features"

# 4. score NEW data with the harvested ensemble — no sampler needed
#    (banks save/load as self-describing npz: bank.save(path)):
#    per-row predictive log-likelihood (logsumexp mixture over samples),
#    posterior feature probabilities, and imputation of missing dims
bank = bank_builder.build()
X_new, _, _ = cambridge_data(N=8, sigma_n=0.5, seed=1)
key = jax.random.key(99)
ll = predict.predictive_loglik(bank, X_new, key)          # (8,) rows
probs = predict.encode(bank, X_new, key)                  # (S, 8, K)
mask = jnp.ones_like(jnp.asarray(X_new)).at[:, 18:].set(0.0)
filled = predict.impute(bank, jnp.asarray(X_new) * mask, mask, key)
print(f"\nbank: S={bank.S} samples at feature bucket K={bank.K}")
print(f"predictive ll of 8 new rows: {float(ll.sum()):.1f} "
      f"(per row {float(ll.mean()):.1f})")
print(f"mean active features per new row: "
      f"{float(probs.mean(0).sum(-1).mean()):.1f}")
err = float(jnp.mean((filled[:, 18:] - jnp.asarray(X_new)[:, 18:]) ** 2))
print(f"imputation MSE on the masked half: {err:.3f}")
print("OK")
