"""LM-substrate example: train a ~100M-param smollm-135m for a few hundred
steps with the framework's data pipeline, AdamW, checkpointing, and
restart-safe driver — the same train_step the multi-pod dry-run lowers at
256/512 chips.

On CPU the full 135M model is exercised with a short schedule by default;
--smoke switches to the reduced same-family config (seconds). All ten
assigned architectures work here via --arch.

    PYTHONPATH=src python examples/train_lm.py                # 135M, short
    PYTHONPATH=src python examples/train_lm.py --smoke        # tiny, fast
    PYTHONPATH=src python examples/train_lm.py --arch minicpm3-4b --smoke
"""
import argparse
import sys

from repro.launch import train

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="smollm-135m")
ap.add_argument("--smoke", action="store_true")
ap.add_argument("--steps", type=int, default=0,
                help="0 -> 300 full / 30 smoke")
args, rest = ap.parse_known_args()

steps = args.steps or (30 if args.smoke else 300)
argv = ["--arch", args.arch, "--steps", str(steps), "--log-every", "10"]
if args.smoke:
    argv.append("--smoke")
else:
    # CPU-feasible tokens/step for the full 135M model
    argv += ["--batch", "4", "--seq", "128"]
sys.exit(train.main(argv + rest))
