"""End-to-end driver: the paper's experiment (Sec. 4) with the production
runtime — fault-tolerant, checkpointed, elastic.

Reproduces the paper's setup: the 1000 x 36 Cambridge set, 1000 iterations,
5 sub-iterations, P processors — through MCMCDriver, which checkpoints every
``--ckpt-every`` iterations and auto-resumes (kill it mid-run and rerun the
same command to see restart; rerun with a different --P to see elastic
re-sharding from the same checkpoint).

    PYTHONPATH=src python examples/cambridge_mcmc.py            # scaled down
    PYTHONPATH=src python examples/cambridge_mcmc.py --paper    # full size
"""
import argparse
import sys

from repro.launch import mcmc

ap = argparse.ArgumentParser()
ap.add_argument("--paper", action="store_true",
                help="full paper-size run (N=1000, 1000 iters; slow on CPU)")
ap.add_argument("--P", type=int, default=5)
args, rest = ap.parse_known_args()

if args.paper:
    argv = ["--N", "1000", "--iters", "1000", "--L", "5", "--P", str(args.P)]
else:
    argv = ["--N", "300", "--iters", "120", "--L", "5", "--P", str(args.P),
            "--K-max", "24"]
sys.exit(mcmc.main(argv + rest))
