"""Distributed IBP inference over a real JAX mesh (shard_map + psum).

Relaunches itself with 8 forced host devices, builds a ('data',) mesh, and
runs the hybrid sampler with X and Z physically sharded across devices —
the production code path that runs unchanged on a TPU pod (launch/mesh.py
builds the (data, model) / (pod, data, model) meshes).

    PYTHONPATH=src python examples/parallel_ibp.py
"""
import os
import sys

if "XLA_FLAGS" not in os.environ:  # relaunch with 8 virtual devices
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.execv(sys.executable, [sys.executable] + sys.argv)

import jax
import jax.numpy as jnp

from repro.core.ibp import IBPHypers, SamplerSpec, build_sampler
from repro.core.ibp.diagnostics import train_joint_loglik
from repro.data import cambridge_data

N, Pn, K_max, K_tail = 320, 8, 16, 6
print(f"devices: {jax.device_count()} | observations: {N} over P={Pn} shards")

X, _, _ = cambridge_data(N=N, sigma_n=0.5, seed=1)

# data="shardmap" puts X and Z physically on a ('data',) mesh of Pn
# devices; build_sampler owns mesh construction and data placement
spec = SamplerSpec(P=Pn, K_max=K_max, K_tail=K_tail, K_init=3, L=5,
                   data="shardmap")
sampler = build_sampler(spec, IBPHypers(), X)
gs, st = sampler.init(jax.random.key(1))

for it in range(60):
    gs, st = sampler.step(gs, st)
    # serialize dispatch: 8 virtual devices share one core here, and
    # letting iterations queue up can starve the collective rendezvous
    jax.block_until_ready(st[0])
    if (it + 1) % 20 == 0:
        Zf = st[0]
        ll = train_joint_loglik(jnp.asarray(sampler.X_global), Zf, gs.A,
                                gs.pi, gs.active, gs.sigma_x)
        print(f"iter {it + 1:3d}: K+ = {int(gs.active.sum())}, "
              f"p' = shard {int(gs.p_prime)}, "
              f"log P(X,Z) = {float(ll):.1f}")

# Z really is distributed: one shard per device
Zf = st[0]
assert len(Zf.sharding.device_set) == Pn

K = int(gs.active.sum())
assert 3 <= K <= 9, K
print(f"\nOK — converged to K+ = {K} features with Z sharded on "
      f"{len(Zf.sharding.device_set)} devices")
