"""Distributed IBP inference over a real JAX mesh (shard_map + psum).

Relaunches itself with 8 forced host devices, builds a ('data',) mesh, and
runs the hybrid sampler with X and Z physically sharded across devices —
the production code path that runs unchanged on a TPU pod (launch/mesh.py
builds the (data, model) / (pod, data, model) meshes).

    PYTHONPATH=src python examples/parallel_ibp.py
"""
import os
import sys

if "XLA_FLAGS" not in os.environ:  # relaunch with 8 virtual devices
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.execv(sys.executable, [sys.executable] + sys.argv)

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.ibp import (IBPHypers, init_hybrid,
                            make_hybrid_iteration_shardmap)
from repro.core.ibp.diagnostics import train_joint_loglik
from repro.data import cambridge_data, shard_rows
from repro import compat

N, Pn, K_max, K_tail = 320, 8, 16, 6
print(f"devices: {jax.device_count()} | observations: {N} over P={Pn} shards")

X, _, _ = cambridge_data(N=N, sigma_n=0.5, seed=1)
Xs = jnp.asarray(shard_rows(X, Pn))

mesh = compat.make_mesh((Pn,), ("data",), axis_types=(compat.AxisType.Auto,))
gs, ss = init_hybrid(jax.random.key(1), Xs, K_max, K_tail=K_tail, K_init=3)
step = make_hybrid_iteration_shardmap(mesh, ("data",), IBPHypers(), L=5,
                                      N_global=N)

with compat.set_mesh(mesh):
    sh = NamedSharding(mesh, P("data"))
    # place each observation shard on its device
    Xf = jax.device_put(Xs.reshape(N, -1), sh)
    Zf = jax.device_put(ss.Z.reshape(N, K_max), sh)
    Zt = jax.device_put(ss.Z_tail.reshape(N, K_tail), sh)
    ta = jax.device_put(ss.tail_active, sh)

    for it in range(60):
        gs, Zf, Zt, ta = step(Xf, gs, Zf, Zt, ta)
        # serialize dispatch: 8 virtual devices share one core here, and
        # letting iterations queue up can starve the collective rendezvous
        jax.block_until_ready(Zf)
        if (it + 1) % 20 == 0:
            ll = train_joint_loglik(jnp.asarray(X), Zf, gs.A, gs.pi,
                                    gs.active, gs.sigma_x)
            print(f"iter {it + 1:3d}: K+ = {int(gs.active.sum())}, "
                  f"p' = shard {int(gs.p_prime)}, "
                  f"log P(X,Z) = {float(ll):.1f}")
    # Z really is distributed: one shard per device
    assert len(Zf.sharding.device_set) == Pn

K = int(gs.active.sum())
assert 3 <= K <= 9, K
print(f"\nOK — converged to K+ = {K} features with Z sharded on "
      f"{len(Zf.sharding.device_set)} devices")
