"""falcon-mamba-7b [ssm; arXiv:2410.05355]: 64L mamba1 blocks, d=4096
(d_inner=8192), ssm_state=16, vocab=65024. Attention-free — long_500k RUNS."""
from .base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="falcon-mamba-7b",
        family="ssm",
        n_layers=64,
        d_model=4096,
        n_heads=1,
        n_kv_heads=1,
        d_ff=0,
        vocab=65024,
        ssm_state=16,
        micro_batches=2,     # d_inner=8192 scan states at full batch
                             # slightly exceed HBM; 2 grad-accum slices
        ssm_conv=4,
        ssm_expand=2,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="falcon-mamba-7b-smoke",
        family="ssm",
        n_layers=2,
        d_model=64,
        n_heads=1,
        n_kv_heads=1,
        d_ff=0,
        vocab=512,
        ssm_state=4,
        ssm_conv=4,
        ssm_expand=2,
        dtype="float32",
        attn_chunk=16,
        scan_chunk=8,
    )
