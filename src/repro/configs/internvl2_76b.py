"""internvl2-76b [vlm; arXiv:2404.16821]: InternViT (STUB: input_specs supply
precomputed patch embeddings) + LLaMA-3-70B-style backbone: 80L, d=8192, 64H
(kv=8), d_ff=28672, vocab=128256."""
from .base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="internvl2-76b",
        family="vlm",
        n_layers=80,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=28672,
        vocab=128256,
        rope_theta=500000.0,
        stub_tokens=256,     # ViT patch embeddings per image (stubbed)
        micro_batches=4,     # 80L x d=8192 train activations exceed 16 GB
                             # HBM at full batch; grad-accumulate 4 slices
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="internvl2-76b-smoke",
        family="vlm",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=512,
        stub_tokens=4,
        dtype="float32",
        attn_chunk=16,
        scan_chunk=8,
    )
