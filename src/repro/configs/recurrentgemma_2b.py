"""recurrentgemma-2b [hybrid; arXiv:2402.19427]: 26L, d=2560, 10H MQA (kv=1,
hd=256), d_ff=7680, vocab=256000. RG-LRU + local attention in 1:2 pattern
(rec, rec, attn), local window 2048, d_rnn=2560. long_500k RUNS (local attn
+ O(1) recurrent state)."""
from .base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b",
        family="hybrid",
        n_layers=26,
        d_model=2560,
        n_heads=10,
        n_kv_heads=1,
        head_dim=256,
        d_ff=7680,
        vocab=256000,
        rglru_pattern=("rec", "rec", "attn"),
        local_window=2048,
        d_rnn=2560,
        ssm_conv=4,
        act="gelu",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b-smoke",
        family="hybrid",
        n_layers=5,  # (rec, rec, attn) + 2 tail rec
        d_model=64,
        n_heads=4,
        n_kv_heads=1,
        head_dim=16,
        d_ff=128,
        vocab=512,
        rglru_pattern=("rec", "rec", "attn"),
        local_window=8,
        d_rnn=64,
        ssm_conv=4,
        act="gelu",
        dtype="float32",
        attn_chunk=16,
        scan_chunk=8,
    )
