"""Config registry: --arch <id> resolution."""
from __future__ import annotations

import importlib

from .base import (
    ALL_SHAPES,
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    TRAIN_4K,
    ModelConfig,
    ShapeConfig,
    shape_applicable,
)

ARCH_IDS = (
    "whisper-large-v3",
    "granite-3-8b",
    "codeqwen1.5-7b",
    "minicpm3-4b",
    "smollm-135m",
    "falcon-mamba-7b",
    "recurrentgemma-2b",
    "deepseek-v2-236b",
    "phi3.5-moe-42b-a6.6b",
    "internvl2-76b",
)

_MODULES = {
    "whisper-large-v3": "whisper_large_v3",
    "granite-3-8b": "granite_3_8b",
    "codeqwen1.5-7b": "codeqwen15_7b",
    "minicpm3-4b": "minicpm3_4b",
    "smollm-135m": "smollm_135m",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "phi3.5-moe-42b-a6.6b": "phi35_moe_42b",
    "internvl2-76b": "internvl2_76b",
}


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.smoke() if smoke else mod.full()


__all__ = [
    "ARCH_IDS",
    "get_config",
    "ModelConfig",
    "ShapeConfig",
    "ALL_SHAPES",
    "TRAIN_4K",
    "PREFILL_32K",
    "DECODE_32K",
    "LONG_500K",
    "shape_applicable",
]
