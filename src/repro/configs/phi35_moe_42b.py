"""phi3.5-moe-42b-a6.6b [moe; hf:microsoft/Phi-3.5-MoE-instruct]: 32L, d=4096,
32H (kv=8), MoE 16 experts top-2, d_ff_expert=6400, vocab=32064."""
from .base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="phi3.5-moe-42b-a6.6b",
        family="moe",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=6400,
        vocab=32064,
        n_experts=16,
        n_shared_experts=0,
        top_k=2,
        d_ff_expert=6400,
        capacity_factor=1.25,
        norm="ln",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="phi3.5-moe-42b-a6.6b-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=512,
        n_experts=4,
        n_shared_experts=0,
        top_k=2,
        d_ff_expert=32,
        capacity_factor=1.25,
        norm="ln",
        dtype="float32",
        attn_chunk=16,
        scan_chunk=8,
    )
