"""deepseek-v2-236b [moe MLA; arXiv:2405.04434]: 60L, d=5120, 128H (kv=128),
MoE 160 routed (top-6, d_ff_expert=1536) + 2 shared, dense d_ff for param
accounting 1536-granular; vocab=102400. MLA kv_lora=512, q_lora=1536, rope 64,
nope 128, v head 128."""
from .base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b",
        family="moe",
        attn="mla",
        n_layers=60,
        d_model=5120,
        n_heads=128,
        n_kv_heads=128,
        head_dim=128,
        d_ff=12288,           # dense first-layer-style ffn unused; experts rule
        vocab=102400,
        kv_lora_rank=512,
        q_lora_rank=1536,
        qk_rope_dim=64,
        qk_nope_dim=128,
        n_experts=160,
        n_shared_experts=2,
        top_k=6,
        d_ff_expert=1536,
        capacity_factor=1.25,
        micro_batches=4,     # 60L x d=5120 + (E,C,d) dispatch buffers exceed
                             # 16 GB HBM at full batch; grad-accumulate
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b-smoke",
        family="moe",
        attn="mla",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab=512,
        kv_lora_rank=16,
        q_lora_rank=32,
        qk_rope_dim=8,
        qk_nope_dim=16,
        n_experts=8,
        n_shared_experts=2,
        top_k=2,
        d_ff_expert=32,
        capacity_factor=1.25,
        dtype="float32",
        attn_chunk=16,
        scan_chunk=8,
    )
