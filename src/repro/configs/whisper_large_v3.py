"""whisper-large-v3 [audio; arXiv:2212.04356]: enc-dec, 32L dec / 32L enc,
d=1280, 20H MHA (kv=20), d_ff=5120, vocab=51866. Conv frontend is a STUB —
input_specs provide precomputed frame embeddings (B, 1500, d)."""
from .base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3",
        family="encdec",
        n_layers=32,
        n_enc_layers=32,
        enc_seq=1500,
        micro_batches=8,     # enc-dec dual-stack activations at B=256 blow
                             # HBM; grad-accumulate 8 slices
        d_model=1280,
        n_heads=20,
        n_kv_heads=20,
        d_ff=5120,
        vocab=51866,
        norm="ln",
        act="gelu",
        gated_mlp=False,
        stub_tokens=1500,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3-smoke",
        family="encdec",
        n_layers=2,
        n_enc_layers=2,
        enc_seq=16,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab=512,
        norm="ln",
        act="gelu",
        gated_mlp=False,
        stub_tokens=16,
        dtype="float32",
        attn_chunk=16,
        scan_chunk=8,
    )
