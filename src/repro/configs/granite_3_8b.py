"""granite-3-8b [dense GQA; hf:ibm-granite]: 40L, d=4096, 32H (kv=8),
d_ff=12800, vocab=49155."""
from .base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="granite-3-8b",
        family="dense",
        n_layers=40,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=12800,
        vocab=49155,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="granite-3-8b-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=512,
        dtype="float32",
        attn_chunk=16,
        scan_chunk=8,
    )
