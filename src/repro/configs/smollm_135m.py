"""smollm-135m [dense; hf:HuggingFaceTB/SmolLM-135M]: 30L, d=576, 9H (kv=3),
d_ff=1536, vocab=49152. llama-arch small; tied embeddings. Also the ~100M
end-to-end training example arch."""
from .base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="smollm-135m",
        family="dense",
        n_layers=30,
        d_model=576,
        n_heads=9,
        n_kv_heads=3,
        d_ff=1536,
        vocab=49152,
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="smollm-135m-smoke",
        family="dense",
        n_layers=2,
        d_model=48,
        n_heads=3,
        n_kv_heads=1,
        d_ff=128,
        vocab=512,
        tie_embeddings=True,
        dtype="float32",
        attn_chunk=16,
        scan_chunk=8,
    )
