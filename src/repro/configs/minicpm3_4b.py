"""minicpm3-4b [dense MLA; hf:openbmb/MiniCPM3-4B]: 62L, d=2560, 40H (kv=40),
d_ff=6400, vocab=73448. MLA: kv_lora=256, q_lora=768, qk rope/nope 32/64,
head 64 (HF config values)."""
from .base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="minicpm3-4b",
        family="dense",
        attn="mla",
        n_layers=62,
        d_model=2560,
        n_heads=40,
        n_kv_heads=40,
        head_dim=64,
        d_ff=6400,
        vocab=73448,
        kv_lora_rank=256,
        q_lora_rank=768,
        qk_rope_dim=32,
        qk_nope_dim=64,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="minicpm3-4b-smoke",
        family="dense",
        attn="mla",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab=512,
        kv_lora_rank=16,
        q_lora_rank=32,
        qk_rope_dim=8,
        qk_nope_dim=16,
        dtype="float32",
        attn_chunk=16,
        scan_chunk=8,
    )
