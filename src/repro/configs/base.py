"""Architecture + run configuration dataclasses.

One ``ModelConfig`` covers all 10 assigned families via optional blocks
(attention flavor, MoE, SSM, RG-LRU hybrid, encoder-decoder, modality stub).
Exact per-arch instances live in src/repro/configs/<id>.py; every file also
exposes ``smoke()`` — a reduced same-family config for CPU tests.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

AttnKind = Literal["gqa", "mla", "none", "local"]
FamilyKind = Literal["dense", "moe", "ssm", "hybrid", "encdec", "vlm"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: FamilyKind
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                    # 0 -> d_model // n_heads
    attn: AttnKind = "gqa"
    norm: Literal["rms", "ln"] = "rms"
    act: Literal["silu", "gelu"] = "silu"
    gated_mlp: bool = True
    learned_pos: bool = False            # whisper-style learned pos-embeds
    tie_embeddings: bool = False
    rope_theta: float = 10000.0

    # --- MLA (minicpm3, deepseek-v2)
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_rope_dim: int = 64
    qk_nope_dim: int = 0                 # 0 -> head_dim

    # --- MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    moe_impl: str = "a2a"                # "a2a" (shard_map all-to-all EP
                                         # dispatch) | "gather" (global-
                                         # capacity baseline; see §Perf)

    # --- SSM (mamba1)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int = 0                 # 0 -> ceil(d_model / 16)

    # --- hybrid (recurrentgemma): pattern of temporal blocks, period 3
    rglru_pattern: tuple[str, ...] = ()  # e.g. ("rec", "rec", "attn")
    local_window: int = 0                # local attention window (hybrid/"local")
    d_rnn: int = 0                       # RG-LRU width (0 -> d_model)

    # --- encoder-decoder (whisper)
    n_enc_layers: int = 0
    enc_seq: int = 0                     # encoder frames (audio stub length)

    # --- modality stub (whisper audio frontend / internvl vision frontend)
    stub_tokens: int = 0                 # patch/frame embeddings provided as input

    # --- execution
    dtype: str = "bfloat16"
    remat: bool = True
    micro_batches: int = 1               # gradient-accumulation slices per
                                         # train step (activation mem ~1/k)
    unroll_layers: bool = False          # unroll scan-over-layers (probes: XLA
                                         # cost_analysis counts a scan body once)
    attn_chunk: int = 1024               # flash-style kv-chunk size
    scan_chunk: int = 128                # ssm/rglru sequence chunk
    logit_dtype: str = "float32"

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def dt_rank(self) -> int:
        return self.ssm_dt_rank or -(-self.d_model // 16)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def param_count(self) -> int:
        """Approximate parameter count (embeddings included once if tied)."""
        d, ff, V, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        hd = self.hd
        n = V * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family == "ssm":
            di = self.d_inner
            per_layer = d * 2 * di + di * self.ssm_conv + \
                di * (self.dt_rank + 2 * self.ssm_state) + self.dt_rank * di + \
                di * self.ssm_state + di + di * d + d
        else:
            if self.attn == "mla":
                qdim = (self.qk_nope_dim or hd) + self.qk_rope_dim
                q_in = self.q_lora_rank or d
                attn_p = (d * self.q_lora_rank if self.q_lora_rank else 0)
                attn_p += q_in * self.n_heads * qdim
                attn_p += d * (self.kv_lora_rank + self.qk_rope_dim)
                attn_p += self.kv_lora_rank * self.n_heads * ((self.qk_nope_dim or hd) + hd)
                attn_p += self.n_heads * hd * d
            else:
                attn_p = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
                    + self.n_heads * hd * d
            mlp_p = 3 * d * ff
            if self.n_experts:
                e_ff = self.d_ff_expert or ff
                mlp_p = d * self.n_experts \
                    + self.n_experts * 3 * d * e_ff \
                    + self.n_shared_experts * 3 * d * e_ff
            per_layer = attn_p + mlp_p + 2 * d
        n += L * per_layer
        if self.family == "hybrid":
            # rough: recurrent blocks ~ attn-sized temporal mixers
            pass
        if self.n_enc_layers:
            n += self.n_enc_layers * (4 * d * d + 3 * d * ff + 2 * d)
            # decoder cross-attention
            n += L * (4 * d * d + d)
        return int(n)

    def param_count_active(self) -> int:
        """Params touched per token (MoE: top_k routed + shared experts)."""
        if not self.n_experts:
            return self.param_count()
        import dataclasses

        dense_like = dataclasses.replace(
            self,
            n_experts=self.top_k,
            capacity_factor=self.capacity_factor,
        )
        # router still sees all E experts
        return dense_like.param_count() + self.n_layers * self.d_model * (
            self.n_experts - self.top_k
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: Literal["train", "prefill", "decode"]


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)

# archs whose attention is sub-quadratic in cached length -> long_500k runs
SUBQUADRATIC = {"falcon-mamba-7b", "recurrentgemma-2b"}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    if shape.name == "long_500k" and cfg.name not in SUBQUADRATIC:
        return False, "pure full-attention arch: 512k decode cache is out of scope (DESIGN.md §5)"
    return True, ""
