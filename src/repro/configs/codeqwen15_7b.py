"""codeqwen1.5-7b [dense; hf:Qwen/CodeQwen1.5-7B]: 32L, d=4096, 32H (kv=32 =>
full MHA), d_ff=13440, vocab=92416. qwen1.5 arch (untied embeddings, SwiGLU)."""
from .base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="codeqwen1.5-7b",
        family="dense",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=32,
        d_ff=13440,
        vocab=92416,
        rope_theta=1000000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="codeqwen1.5-7b-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=160,
        vocab=512,
        dtype="float32",
        attn_chunk=16,
        scan_chunk=8,
    )
