"""Dispatching wrapper for the collapsed_row bit-flip recurrence.

``collapsed_row_flip(..., flavor=...)`` selects the implementation:

* ``"jnp"``    — the pure-jnp oracle (full-K lax.scan, bitwise the seed
  sampler's inner loop). The ``backend="ref"`` sampler uses this.
* ``"packed"`` — the CPU-fast form: O(K) per bit (rss/rH carry) over the
  packed active columns only (dynamic-bound while_loop). The
  ``backend="fast"`` sampler uses this.
* ``"pallas"`` — the Pallas kernel, full-K mean-form like "jnp" (compiled
  on TPU; ``interpret=True`` elsewhere, decided once via
  ``kernels/_backend.py``). Selected by the sampler's ``backend="pallas"``.

No jit here: the caller (``core/ibp/collapsed.py``) traces this inside an
already-jitted row scan, and ``flavor`` is static by construction.
"""
from __future__ import annotations

from repro.kernels._backend import default_interpret

from .fast import collapsed_row_flip_fast
from .kernel import collapsed_row_flip_pallas
from .ref import collapsed_row_flip_ref

FLAVORS = ("jnp", "packed", "pallas")


def collapsed_row_flip(
    M, H, x_n, z, v, q, mean, u, m_minus, active_m, N, inv2s2,
    *, flavor: str = "jnp",
):
    """Run the K-sequential bit-flip recurrence; returns (z, v, q, mean)."""
    if flavor not in FLAVORS:
        raise ValueError(f"flavor={flavor!r} not in {FLAVORS}")
    if flavor == "pallas":
        return collapsed_row_flip_pallas(
            M, H, x_n, z, v, q, mean, u, m_minus, active_m, N, inv2s2,
            interpret=default_interpret(),
        )
    if flavor == "packed":
        return collapsed_row_flip_fast(
            M, H, x_n, z, v, q, mean, u, m_minus, active_m, N, inv2s2
        )
    return collapsed_row_flip_ref(
        M, H, x_n, z, v, q, mean, u, m_minus, active_m, N, inv2s2
    )
