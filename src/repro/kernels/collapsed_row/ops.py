"""Dispatching wrapper for the collapsed_row bit-flip recurrence.

``collapsed_row_flip(..., flavor=...)`` selects the implementation:

* ``"jnp"``    — the pure-jnp oracle (full-K lax.scan, bitwise the seed
  sampler's inner loop). The ``backend="ref"`` sampler uses this.
* ``"packed"`` — the CPU-fast form: O(K) per bit (rss/rH carry) over the
  packed active columns only (dynamic-bound while_loop). The
  ``backend="fast"`` sampler uses this.
* ``"pallas"`` — the Pallas kernel, full-K mean-form like "jnp" (compiled
  on TPU; ``interpret=True`` elsewhere, decided once via
  ``kernels/_backend.py``). Selected by the sampler's ``backend="pallas"``.

No jit here: the caller (``core/ibp/collapsed.py``) traces this inside an
already-jitted row scan, and ``flavor`` is static by construction.

Occupancy-adaptive packing (DESIGN.md §14): under ``k_live_buckets="on"``
the caller passes the K_live BLOCK (all live columns + the lowest free
slots, canonically ordered) rather than the K_max pad — every flavor is
shape-generic, so K below is whichever width the caller packed to. The
``packed`` flavor additionally accepts the carried ``G = H Hᵀ`` so its
per-bit moves stay O(K) without the per-row O(K²D) GEMM.
"""
from __future__ import annotations

from repro.kernels._backend import default_interpret

from .fast import collapsed_row_flip_fast
from .kernel import collapsed_row_flip_pallas
from .ref import collapsed_row_flip_ref

FLAVORS = ("jnp", "packed", "pallas")


def collapsed_row_flip(
    M, H, x_n, z, v, q, mean, u, m_minus, active_m, N, inv2s2,
    *, flavor: str = "jnp", G=None,
):
    """Run the K-sequential bit-flip recurrence; returns (z, v, q, mean).

    ``G`` (optional) is the caller-carried H Hᵀ; only the ``packed``
    flavor consumes it (the mean-form flavors never materialize G).
    """
    if flavor not in FLAVORS:
        raise ValueError(f"flavor={flavor!r} not in {FLAVORS}")
    if flavor == "pallas":
        return collapsed_row_flip_pallas(
            M, H, x_n, z, v, q, mean, u, m_minus, active_m, N, inv2s2,
            interpret=default_interpret(),
        )
    if flavor == "packed":
        return collapsed_row_flip_fast(
            M, H, x_n, z, v, q, mean, u, m_minus, active_m, N, inv2s2, G=G
        )
    return collapsed_row_flip_ref(
        M, H, x_n, z, v, q, mean, u, m_minus, active_m, N, inv2s2
    )
