from .ops import collapsed_row_flip
from .ref import collapsed_row_flip_ref
from .fast import collapsed_row_flip_fast
from .kernel import collapsed_row_flip_pallas

__all__ = [
    "collapsed_row_flip",
    "collapsed_row_flip_ref",
    "collapsed_row_flip_fast",
    "collapsed_row_flip_pallas",
]
