"""CPU-fast flavor of the collapsed_row bit-flip recurrence.

Same posterior-predictive semantics as ``collapsed_row_flip_ref``, two
exactness-preserving rewrites (DESIGN.md §12):

* **O(K) per bit instead of O(K + D).** The likelihood only consumes the
  residual through its norm, so carry (rss = ‖x − zH‖², rH = H (x − zH))
  instead of the (D,)-dim mean: a flip moves them by (±2 rH_k + G_kk,
  ∓G[k]) with G = H Hᵀ. The occupancy-adaptive row step (DESIGN.md §14)
  CARRIES G across rows by the rank-two corrections matching each H move
  and passes it in — the strict O(K² + KD) row bound. When ``G`` is not
  supplied (legacy unpacked path, ``k_live_buckets="off"``), it is
  recomputed here per row as a single O(K²D) GEMM — the historical
  constants-for-big-O trade (DESIGN.md §12). The mean is reconstructed
  once (z @ H) on exit.
* **Packed-active iteration.** Inactive columns are exact no-ops of the
  recurrence (z_k = 0, flips masked), so the loop visits only the packed
  indices of ``active_m``, in increasing order — identical decisions to
  the full-K scan, with the trip count K₊ instead of K_max. On CPU this
  is a dynamic-bound while_loop; on TPU lockstep SIMD makes packing
  pointless, which is why the Pallas kernel keeps the full-K form.
  Under occupancy-adaptive packing every input is already the K_live
  block (K here = the bucket size, not K_max); nothing changes — the
  recurrence is shape-generic and the block is ordered canonically.

The float arithmetic differs from the ref form (incremental rss vs
fresh residual dots), so decisions can differ from ref's at
measure-zero likelihood-boundary events — the backend equivalence test
(tests/test_collapsed_fast.py) quantifies exactly this.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def collapsed_row_flip_fast(
    M: Array,         # (K, K) masked posterior map, symmetric
    H: Array,         # (K, D) posterior mean map
    x_n: Array,       # (D,)
    z: Array,         # (K,)
    v: Array,         # (K,) = M @ z
    q: Array,         # ()   = z @ v
    mean: Array,      # (D,) = z @ H
    u: Array,         # (K,) logit-uniform accept thresholds
    m_minus: Array,   # (K,)
    active_m: Array,  # (K,)
    N: Array,         # ()
    inv2s2: Array,    # ()
    G: Array | None = None,  # (K, K) = H Hᵀ, carried by the caller
) -> tuple[Array, Array, Array, Array]:
    """Returns (z, v, q, mean) — see collapsed_row_flip_ref for semantics."""
    K = z.shape[0]
    D = x_n.shape[0]
    if G is None:
        G = H @ H.T
    r = x_n - mean
    rss = jnp.dot(r, r)
    rH = H @ r
    logprior = jnp.log(jnp.maximum(m_minus, 1e-20)) - jnp.log(N - m_minus)
    ks = jnp.nonzero(active_m > 0.5, size=K, fill_value=0)[0]
    n_act = jnp.sum(active_m > 0.5).astype(jnp.int32)

    def body(c):
        i, z, v, q, rss, rH = c
        k = ks[i]
        zk = z[k]
        Mk = M[k]       # == M[:, k] (M symmetric)
        Mkk = Mk[k]
        Gk = G[k]
        Gkk = Gk[k]
        # state with bit k = 0
        v0 = v - zk * Mk
        q0 = q - zk * (2.0 * v[k] - Mkk)
        rH0 = rH + zk * Gk
        rss0 = rss + zk * (2.0 * rH[k] + Gkk)
        # state with bit k = 1
        v1 = v0 + Mk
        q1 = q0 + 2.0 * v0[k] + Mkk
        rss1 = rss0 - 2.0 * rH0[k] + Gkk
        s0 = 1.0 + q0
        s1 = 1.0 + q1
        ll0 = -0.5 * D * jnp.log(s0) - inv2s2 * rss0 / s0
        ll1 = -0.5 * D * jnp.log(s1) - inv2s2 * rss1 / s1
        logodds = logprior[k] + ll1 - ll0
        may = m_minus[k] > 0.5  # k is active by construction of ks
        znk = jnp.where(may, (logodds > u[k]).astype(z.dtype), zk)
        pick1 = znk > 0.5
        v = jnp.where(pick1, v1, v0)
        q = jnp.where(pick1, q1, q0)
        rss = jnp.where(pick1, rss1, rss0)
        rH = jnp.where(pick1, rH0 - Gk, rH0)
        return i + 1, z.at[k].set(znk), v, q, rss, rH

    c0 = (jnp.int32(0), z, v, q, rss, rH)
    _, z, v, q, rss, rH = jax.lax.while_loop(
        lambda c: c[0] < n_act, body, c0
    )
    return z, v, q, z @ H
