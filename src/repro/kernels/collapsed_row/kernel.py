"""Pallas TPU kernel: the K-sequential collapsed-row bit-flip recurrence.

TPU adaptation (DESIGN.md §12): unlike ``gibbs_flip`` there is no row
blocking — the collapsed recurrence is sequential in k BY CONSTRUCTION
(each flip conditions on all previous flips through (v, q, mean)), and
it runs on one row at a time inside the row scan. The win is locality:
M (K, K), H (K, D) and the whole carry (z, v, q, mean) stay VMEM-resident
across all K steps, so the recurrence never touches HBM after the first
load — at K = 64, D = 1024 that is ~280 KB ≪ 16 MB VMEM.

All per-k selections use one-hot contractions instead of dynamic slicing
(lane-dim dynamic indexing is layout-hostile on TPU; one-hot matvecs hit
the MXU/VPU). M is passed TRANSPOSED so the one-hot row contraction
``onehot @ Mt`` yields column M[:, k] — bitwise the same values the jnp
oracle reads.

Occupancy-adaptive packing (DESIGN.md §14): when the caller runs the
packed row step, every operand here is already the K_live BLOCK — K
below is the bucket size, not K_max, so the sequential recurrence runs
K_live one-hot contractions instead of K_max and the VMEM-resident
(M, H) footprint shrinks quadratically/linearly with the bucket. The
kernel itself is shape-generic: the block is canonically ordered and
free in-block slots are exact no-ops (act = 0), so no packing logic
lives on this side.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(mt_ref, h_ref, x_ref, z_ref, v_ref, q_ref, mean_ref, u_ref,
            mm_ref, act_ref, n_ref, s_ref,
            zout_ref, vout_ref, qout_ref, meanout_ref):
    Mt = mt_ref[...]          # (K, K) = M^T; row k of Mt == M[:, k]
    H = h_ref[...]            # (K, D)
    x = x_ref[...]            # (1, D)
    z = z_ref[...]            # (1, K)
    v = v_ref[...]            # (1, K)
    q = q_ref[0, 0]           # scalar
    mean = mean_ref[...]      # (1, D)
    u = u_ref[...]            # (1, K)
    mm = mm_ref[...]          # (1, K)
    act = act_ref[...]        # (1, K)
    N = n_ref[0, 0]           # scalar
    inv2s2 = s_ref[0, 0]      # scalar

    K = z.shape[1]
    D = x.shape[1]
    kidx = jax.lax.broadcasted_iota(jnp.int32, (1, K), 1)

    def body(k, carry):
        z, v, q, mean = carry
        onehot = (kidx == k).astype(jnp.float32)              # (1, K)
        Mk = jnp.dot(onehot, Mt, preferred_element_type=jnp.float32)  # (1, K) = M[:, k]
        Hk = jnp.dot(onehot, H, preferred_element_type=jnp.float32)   # (1, D)
        Mkk = jnp.sum(Mk * onehot)
        zk = jnp.sum(z * onehot)
        vk = jnp.sum(v * onehot)
        uk = jnp.sum(u * onehot)
        mk = jnp.sum(mm * onehot)
        act_k = jnp.sum(act * onehot)
        # state with bit k = 0
        v0 = v - zk * Mk
        q0 = q - zk * (2.0 * vk - Mkk)
        mean0 = mean - zk * Hk
        # state with bit k = 1
        v0k = jnp.sum(v0 * onehot)
        v1 = v0 + Mk
        q1 = q0 + 2.0 * v0k + Mkk
        mean1 = mean0 + Hk
        s0 = 1.0 + q0
        s1 = 1.0 + q1
        r0 = x - mean0
        r1 = x - mean1
        ll0 = -0.5 * D * jnp.log(s0) - inv2s2 * jnp.sum(r0 * r0) / s0
        ll1 = -0.5 * D * jnp.log(s1) - inv2s2 * jnp.sum(r1 * r1) / s1
        logodds = jnp.log(jnp.maximum(mk, 1e-20)) - jnp.log(N - mk) + ll1 - ll0
        may = (act_k > 0) & (mk > 0.5)
        take1 = (logodds > uk).astype(jnp.float32)
        znk = jnp.where(may, take1, zk)
        pick1 = znk > 0.5
        v = jnp.where(pick1, v1, v0)
        q = jnp.where(pick1, q1, q0)
        mean = jnp.where(pick1, mean1, mean0)
        z = z * (1.0 - onehot) + znk * onehot
        return z, v, q, mean

    z, v, q, mean = jax.lax.fori_loop(0, K, body, (z, v, q, mean))
    zout_ref[...] = z
    vout_ref[...] = v
    qout_ref[0, 0] = q
    meanout_ref[...] = mean


def collapsed_row_flip_pallas(
    M: jax.Array,         # (K, K) symmetric masked posterior map
    H: jax.Array,         # (K, D)
    x_n: jax.Array,       # (D,)
    z: jax.Array,         # (K,)
    v: jax.Array,         # (K,)
    q: jax.Array,         # ()
    mean: jax.Array,      # (D,)
    u: jax.Array,         # (K,)
    m_minus: jax.Array,   # (K,)
    active_m: jax.Array,  # (K,)
    N: jax.Array,         # ()
    inv2s2: jax.Array,    # ()
    *,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    K = z.shape[0]
    D = x_n.shape[0]
    f32 = jnp.float32
    full = lambda shape: pl.BlockSpec(shape, lambda i: (0, 0))

    zo, vo, qo, mo = pl.pallas_call(
        _kernel,
        grid=(1,),
        in_specs=[
            full((K, K)),   # M^T
            full((K, D)),   # H
            full((1, D)),   # x_n
            full((1, K)),   # z
            full((1, K)),   # v
            full((1, 1)),   # q
            full((1, D)),   # mean
            full((1, K)),   # u
            full((1, K)),   # m_minus
            full((1, K)),   # active_m
            full((1, 1)),   # N
            full((1, 1)),   # inv2s2
        ],
        out_specs=[
            full((1, K)), full((1, K)), full((1, 1)), full((1, D)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, K), f32),
            jax.ShapeDtypeStruct((1, K), f32),
            jax.ShapeDtypeStruct((1, 1), f32),
            jax.ShapeDtypeStruct((1, D), f32),
        ],
        interpret=interpret,
    )(
        M.T.astype(f32),
        H.astype(f32),
        x_n.reshape(1, D).astype(f32),
        z.reshape(1, K).astype(f32),
        v.reshape(1, K).astype(f32),
        jnp.asarray(q, f32).reshape(1, 1),
        mean.reshape(1, D).astype(f32),
        u.reshape(1, K).astype(f32),
        m_minus.reshape(1, K).astype(f32),
        active_m.reshape(1, K).astype(f32),
        jnp.asarray(N, f32).reshape(1, 1),
        jnp.asarray(inv2s2, f32).reshape(1, 1),
    )
    return zo[0], vo[0], qo[0, 0], mo[0]
