"""Pure-jnp oracle for the collapsed_row kernel.

Semantics: the K-sequential collapsed Gibbs bit-flip recurrence for ONE
row n of Z (Griffiths & Ghahramani posterior-predictive form). Given the
row-deleted posterior map M = (Z_-^T Z_- + r I)^{-1} (masked to active
columns), H = M Z_-^T X_-, and the carried quadratic state
(v = M z, q = z^T M z, mean = z H), flip every bit k in order:

    x_n | z ~ N( z H,  sigma_x^2 (1 + z M z^T) I )

with prior odds m_k / (N - m_k). Each step is O(K + D): the flip moves
(v, q, mean) by (+-M[:, k], +-2 v_k + M_kk, +-H[k]) instead of re-solving.

This is the INNER LOOP of the collapsed sampler — the fast
``backend="fast"`` row step (core/ibp/collapsed.py) carries (L, M, H)
across rows with rank-one up/downdates and hands this recurrence the
same arguments the O(K^3) oracle computes from scratch, so ref and
kernel must agree bitwise given identical inputs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def collapsed_row_flip_ref(
    M: Array,         # (K, K) masked posterior map, symmetric
    H: Array,         # (K, D) posterior mean map
    x_n: Array,       # (D,) the row's observation (or residual)
    z: Array,         # (K,) current bits (row-deleted singletons cleared)
    v: Array,         # (K,) = M @ z
    q: Array,         # ()   = z @ v
    mean: Array,      # (D,) = z @ H
    u: Array,         # (K,) logit-uniform accept thresholds
    m_minus: Array,   # (K,) column counts with row n removed
    active_m: Array,  # (K,) live-column mask
    N: Array,         # ()   GLOBAL observation count (prior odds)
    inv2s2: Array,    # ()   = 1 / (2 sigma_x^2)
) -> tuple[Array, Array, Array, Array]:
    """Returns (z, v, q, mean) after one in-order pass over all K bits."""
    D = x_n.shape[0]
    K = z.shape[0]

    def bit_body(c, k):
        z, v, q, mean = c
        zk = z[k]
        Mk = M[:, k]
        Mkk = M[k, k]
        Hk = H[k]
        # state with bit k = 0
        v0 = v - zk * Mk
        q0 = q - zk * (2.0 * v[k] - Mkk)
        mean0 = mean - zk * Hk
        # state with bit k = 1
        v1 = v0 + Mk
        q1 = q0 + 2.0 * v0[k] + Mkk
        mean1 = mean0 + Hk
        s0 = 1.0 + q0
        s1 = 1.0 + q1
        r0 = x_n - mean0
        r1 = x_n - mean1
        ll0 = -0.5 * D * jnp.log(s0) - inv2s2 * jnp.dot(r0, r0) / s0
        ll1 = -0.5 * D * jnp.log(s1) - inv2s2 * jnp.dot(r1, r1) / s1
        mk = m_minus[k]
        logodds = jnp.log(jnp.maximum(mk, 1e-20)) - jnp.log(N - mk) + ll1 - ll0
        # sample; only live columns with support may flip
        may = (active_m[k] > 0) & (mk > 0.5)
        take1 = logodds > u[k]
        znk = jnp.where(may, take1.astype(z.dtype), z[k])
        pick1 = znk > 0.5
        v = jnp.where(pick1, v1, v0)
        q = jnp.where(pick1, q1, q0)
        mean = jnp.where(pick1, mean1, mean0)
        z = z.at[k].set(znk)
        return (z, v, q, mean), None

    (z, v, q, mean), _ = jax.lax.scan(bit_body, (z, v, q, mean), jnp.arange(K))
    return z, v, q, mean
