"""Shared backend detection for the Pallas kernel packages.

Every kernel wrapper needs the same decision: compile the Pallas body on
TPU, fall back to ``interpret=True`` elsewhere (this container is
CPU-only, so interpret mode is the validation path). The decision is a
property of the process' platform, not of any traced value, so it is
made ONCE and cached — each jitted wrapper then bakes it in as a static
argument at trace time instead of re-querying ``jax.default_backend()``
on every call (which each kernel package used to re-implement as its
own ``_on_tpu()``).
"""
from __future__ import annotations

import functools

import jax


@functools.cache
def on_tpu() -> bool:
    """True when the default JAX backend is TPU (cached per process)."""
    return jax.default_backend() == "tpu"


def default_interpret() -> bool:
    """Static ``interpret=`` default for pallas_call wrappers."""
    return not on_tpu()
