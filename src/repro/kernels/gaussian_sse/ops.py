"""Jitted wrapper for gaussian_sse: padding + backend select."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import DEFAULT_BLOCK_N, gaussian_sse_pallas

Array = jax.Array


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("block_n", "interpret"))
def gaussian_sse_core(
    X: Array,
    Z: Array,
    A: Array,
    active: Array,
    block_n: int = DEFAULT_BLOCK_N,
    interpret: bool = True,
) -> Array:
    N = X.shape[0]
    bn = min(block_n, max(8, N))
    pad = (-N) % bn
    if pad:  # zero rows have zero residual: X=0, Z=0 -> r=0
        X = jnp.pad(X, ((0, pad), (0, 0)))
        Z = jnp.pad(Z, ((0, pad), (0, 0)))
    return gaussian_sse_pallas(X, Z, A, active, block_n=bn, interpret=interpret)


def gaussian_sse(
    X: Array, Z: Array, A: Array, active: Array, block_n: int = DEFAULT_BLOCK_N
) -> Array:
    return gaussian_sse_core(X, Z, A, active, block_n=block_n, interpret=not _on_tpu())
