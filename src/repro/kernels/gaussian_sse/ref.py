"""Pure-jnp oracle: masked residual sum of squares ||X - (Z*active) A||^2."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def gaussian_sse_ref(X: Array, Z: Array, A: Array, active: Array) -> Array:
    Xf = X.astype(jnp.float32)
    Zf = Z.astype(jnp.float32) * active.astype(jnp.float32)[None, :]
    R = Xf - Zf @ A.astype(jnp.float32)
    return jnp.sum(R * R)
