from . import ops, ref
from .kernel import gaussian_sse_pallas
from .ops import gaussian_sse, gaussian_sse_core
from .ref import gaussian_sse_ref

__all__ = [
    "ops",
    "ref",
    "gaussian_sse",
    "gaussian_sse_core",
    "gaussian_sse_pallas",
    "gaussian_sse_ref",
]
