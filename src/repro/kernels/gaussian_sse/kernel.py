"""Pallas TPU kernel: fused masked-residual SSE.

sigma_x's posterior needs ||X - Z A||^2 right after the master A draw. The
naive lowering materializes the (N_p, D) residual in HBM (write + re-read);
this kernel fuses (mask -> matmul -> subtract -> square -> reduce) per VMEM
block and accumulates a single f32 scalar across the grid.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 512


def _kernel(x_ref, z_ref, a_ref, act_ref, out_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    xb = x_ref[...]                       # (BN, D)
    zb = z_ref[...] * act_ref[...]        # (BN, K) masked
    r = xb - jnp.dot(zb, a_ref[...], preferred_element_type=jnp.float32)
    out_ref[0, 0] += jnp.sum(r * r)


def gaussian_sse_pallas(
    X: jax.Array,
    Z: jax.Array,
    A: jax.Array,
    active: jax.Array,
    *,
    block_n: int = DEFAULT_BLOCK_N,
    interpret: bool = False,
) -> jax.Array:
    N, D = X.shape
    K = Z.shape[1]
    assert N % block_n == 0, (N, block_n)
    grid = (N // block_n,)

    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, D), lambda i: (i, 0)),
            pl.BlockSpec((block_n, K), lambda i: (i, 0)),
            pl.BlockSpec((K, D), lambda i: (0, 0)),
            pl.BlockSpec((1, K), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=interpret,
    )(
        X.astype(jnp.float32),
        Z.astype(jnp.float32),
        A.astype(jnp.float32),
        active.reshape(1, K).astype(jnp.float32),
    )
    return out[0, 0]
