"""Jitted wrapper for feature_stats: padding + backend select."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import DEFAULT_BLOCK_N, feature_stats_pallas

Array = jax.Array


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("block_n", "interpret"))
def feature_stats_core(
    X: Array, Z: Array, block_n: int = DEFAULT_BLOCK_N, interpret: bool = True
) -> tuple[Array, Array, Array]:
    N = X.shape[0]
    bn = min(block_n, max(8, N))
    pad = (-N) % bn
    if pad:  # zero rows contribute nothing to any of the three stats
        X = jnp.pad(X, ((0, pad), (0, 0)))
        Z = jnp.pad(Z, ((0, pad), (0, 0)))
    return feature_stats_pallas(X, Z, block_n=bn, interpret=interpret)


def feature_stats(
    X: Array, Z: Array, block_n: int = DEFAULT_BLOCK_N
) -> tuple[Array, Array, Array]:
    return feature_stats_core(X, Z, block_n=block_n, interpret=not _on_tpu())
