"""Pure-jnp oracle: the master-sync sufficient statistics in one definition."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def feature_stats_ref(X: Array, Z: Array) -> tuple[Array, Array, Array]:
    """Returns (ZtZ (K,K), ZtX (K,D), m (K,))."""
    Zf = Z.astype(jnp.float32)
    Xf = X.astype(jnp.float32)
    return Zf.T @ Zf, Zf.T @ Xf, jnp.sum(Zf, axis=0)
