"""Pallas TPU kernel: fused sufficient-statistics Gram accumulation.

The hybrid sampler's master sync needs (ZtZ, ZtX, m) — three reductions over
the same (N_p, ·) operands. Fusing them into one grid pass reads Z and X from
HBM exactly once (beyond-paper optimization #2 in DESIGN.md §7); unfused XLA
emits three GEMM/reduce ops each re-streaming Z.

Accumulation pattern: every grid step maps to the same output block; step 0
initializes, later steps add. Output stays in VMEM for the whole grid walk
(K·K + K·D + K floats ≪ VMEM).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 512


def _kernel(x_ref, z_ref, ztz_ref, ztx_ref, m_ref):
    zb = z_ref[...]   # (BN, K)
    xb = x_ref[...]   # (BN, D)

    @pl.when(pl.program_id(0) == 0)
    def _init():
        ztz_ref[...] = jnp.zeros_like(ztz_ref)
        ztx_ref[...] = jnp.zeros_like(ztx_ref)
        m_ref[...] = jnp.zeros_like(m_ref)

    ztz_ref[...] += jnp.dot(zb.T, zb, preferred_element_type=jnp.float32)
    ztx_ref[...] += jnp.dot(zb.T, xb, preferred_element_type=jnp.float32)
    m_ref[...] += jnp.sum(zb, axis=0, keepdims=True)


def feature_stats_pallas(
    X: jax.Array,
    Z: jax.Array,
    *,
    block_n: int = DEFAULT_BLOCK_N,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    N, D = X.shape
    K = Z.shape[1]
    assert N % block_n == 0, (N, block_n)
    grid = (N // block_n,)

    ztz, ztx, m = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, D), lambda i: (i, 0)),
            pl.BlockSpec((block_n, K), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((K, K), lambda i: (0, 0)),
            pl.BlockSpec((K, D), lambda i: (0, 0)),
            pl.BlockSpec((1, K), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((K, K), jnp.float32),
            jax.ShapeDtypeStruct((K, D), jnp.float32),
            jax.ShapeDtypeStruct((1, K), jnp.float32),
        ],
        interpret=interpret,
    )(X.astype(jnp.float32), Z.astype(jnp.float32))
    return ztz, ztx, m[0]
