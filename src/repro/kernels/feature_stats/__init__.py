from . import ops, ref
from .kernel import feature_stats_pallas
from .ops import feature_stats, feature_stats_core
from .ref import feature_stats_ref

__all__ = [
    "ops",
    "ref",
    "feature_stats",
    "feature_stats_core",
    "feature_stats_pallas",
    "feature_stats_ref",
]
