"""Pallas TPU kernel: blocked uncollapsed Gibbs sweep (the sampler hot loop).

TPU adaptation (DESIGN.md §4): rows are independent — tile them into VMEM
blocks of BLOCK_N; the (K, D) feature matrix A stays VMEM-resident across the
whole sequential k-loop, and the (BLOCK_N, D) residual is the loop carry, so
the K-step recurrence never touches HBM. Per k step the compute is two
(BLOCK_N, D) x (D,) MXU products — arithmetic intensity ~K× higher than the
naive form that re-reads X/Z/A from HBM every step.

All per-k selections use one-hot contractions instead of dynamic slicing —
lane-dim dynamic indexing is layout-hostile on TPU; one-hot matvecs hit the
MXU/VPU instead.

VMEM budget per block (f32): BLOCK_N·D (x, res) ·2 + BLOCK_N·K (z, u) ·2
+ K·D (A) + O(K). For BLOCK_N=256, D≤1024, K≤64: ~2.6 MB ≪ 16 MB VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 256


def _kernel(x_ref, z_ref, a_ref, lpi_ref, act_ref, anorm_ref, u_ref, s_ref,
            zout_ref):
    x = x_ref[...]            # (BN, D)
    z = z_ref[...]            # (BN, K)
    A = a_ref[...]            # (K, D)
    lpi = lpi_ref[...]        # (1, K)
    act = act_ref[...]        # (1, K)
    anorm = anorm_ref[...]    # (1, K)
    u = u_ref[...]            # (BN, K)
    inv2s2 = s_ref[0, 0]      # scalar

    K = z.shape[1]
    res = x - jnp.dot(z, A, preferred_element_type=jnp.float32)
    kidx = jax.lax.broadcasted_iota(jnp.int32, (1, K), 1)

    def body(k, carry):
        res, z = carry
        onehot = (kidx == k).astype(jnp.float32)          # (1, K)
        a_k = jnp.dot(onehot, A, preferred_element_type=jnp.float32)  # (1, D)
        z_k = jnp.sum(z * onehot, axis=1)                 # (BN,)
        u_k = jnp.sum(u * onehot, axis=1)                 # (BN,)
        anorm_k = jnp.sum(anorm * onehot)
        lpi_k = jnp.sum(lpi * onehot)
        act_k = jnp.sum(act * onehot)
        # residual with bit k cleared: dot against a_k
        s = jnp.sum(res * a_k, axis=1)                    # (BN,) = res @ a_k
        s0 = s + z_k * anorm_k
        logits = lpi_k + (2.0 * s0 - anorm_k) * inv2s2
        znew = jnp.where(act_k > 0, (logits > u_k).astype(z.dtype), z_k)
        delta = z_k - znew                                # (BN,)
        res = res + delta[:, None] * a_k
        z = z * (1.0 - onehot) + znew[:, None] * onehot
        return res, z

    res, z = jax.lax.fori_loop(0, K, body, (res, z))
    zout_ref[...] = z


def gibbs_flip_pallas(
    X: jax.Array,
    Z: jax.Array,
    A: jax.Array,
    logit_pi: jax.Array,
    active: jax.Array,
    u_logit: jax.Array,
    inv2s2: jax.Array,
    *,
    block_n: int = DEFAULT_BLOCK_N,
    interpret: bool = False,
) -> jax.Array:
    """N must be a multiple of block_n (ops.py pads)."""
    N, D = X.shape
    K = Z.shape[1]
    assert N % block_n == 0, (N, block_n)
    grid = (N // block_n,)

    row_block = lambda shape: pl.BlockSpec(shape, lambda i: (i, 0))
    full = lambda shape: pl.BlockSpec(shape, lambda i: (0, 0))

    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            row_block((block_n, D)),   # X
            row_block((block_n, K)),   # Z
            full((K, D)),              # A
            full((1, K)),              # logit_pi
            full((1, K)),              # active
            full((1, K)),              # anorm2
            row_block((block_n, K)),   # u_logit
            full((1, 1)),              # inv2s2
        ],
        out_specs=row_block((block_n, K)),
        out_shape=jax.ShapeDtypeStruct((N, K), jnp.float32),
        interpret=interpret,
    )(
        X.astype(jnp.float32),
        Z.astype(jnp.float32),
        A.astype(jnp.float32),
        logit_pi.reshape(1, K).astype(jnp.float32),
        active.reshape(1, K).astype(jnp.float32),
        jnp.sum(A.astype(jnp.float32) ** 2, axis=1).reshape(1, K),
        u_logit.astype(jnp.float32),
        jnp.asarray(inv2s2, jnp.float32).reshape(1, 1),
    )
