from . import ops, ref
from .kernel import gibbs_flip_pallas
from .ops import gibbs_flip, gibbs_flip_core
from .ref import gibbs_flip_ref

__all__ = [
    "ops",
    "ref",
    "gibbs_flip",
    "gibbs_flip_core",
    "gibbs_flip_pallas",
    "gibbs_flip_ref",
]
