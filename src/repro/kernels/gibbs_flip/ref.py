"""Pure-jnp oracle for the gibbs_flip kernel.

Semantics: one uncollapsed Gibbs sweep of Z | pi, A over all K columns
(sequential in k, vectorized over rows), with pre-drawn logit-uniforms.
Must match repro.core.ibp.sweeps._uncollapsed_sweep_jnp given the same
uniforms — the kernel and the sampler share this contract.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def gibbs_flip_ref(
    X: Array,        # (N, D)
    Z: Array,        # (N, K) in {0,1}
    A: Array,        # (K, D)
    logit_pi: Array, # (K,)
    active: Array,   # (K,) in {0,1}
    u_logit: Array,  # (N, K) logit-uniforms
    inv2s2: Array,   # () = 1 / (2 sigma_x^2)
) -> Array:
    R = X - Z @ A
    anorm2 = jnp.sum(A * A, axis=1)

    def body(carry, k):
        R, Z = carry
        a_k = A[k]
        z_k = Z[:, k]
        R0 = R + z_k[:, None] * a_k[None, :]
        dll = (2.0 * (R0 @ a_k) - anorm2[k]) * inv2s2
        logits = logit_pi[k] + dll
        znew = jnp.where(active[k] > 0, (logits > u_logit[:, k]).astype(Z.dtype), z_k)
        R = R0 - znew[:, None] * a_k[None, :]
        Z = Z.at[:, k].set(znew)
        return (R, Z), None

    (R, Z), _ = jax.lax.scan(body, (R, Z), jnp.arange(Z.shape[1]))
    return Z
