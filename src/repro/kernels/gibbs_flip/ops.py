"""Jitted public wrapper for the gibbs_flip kernel.

Handles padding to the row-block size, dtype policy (compute f32, return the
input Z dtype), drawing the logit-uniform slab from a PRNG key, and backend
selection (Pallas compiled on TPU, interpret=True elsewhere — this container
is CPU-only so interpret mode is the validation path).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels._backend import default_interpret

from .kernel import DEFAULT_BLOCK_N, gibbs_flip_pallas

Array = jax.Array


def _logit(p: Array) -> Array:
    p = jnp.clip(p, 1e-6, 1.0 - 1e-6)
    return jnp.log(p) - jnp.log1p(-p)


@partial(jax.jit, static_argnames=("block_n", "interpret"))
def gibbs_flip_core(
    X: Array,
    Z: Array,
    A: Array,
    logit_pi: Array,
    active: Array,
    u_logit: Array,
    inv2s2: Array,
    block_n: int = DEFAULT_BLOCK_N,
    interpret: bool = True,
) -> Array:
    N, D = X.shape
    K = Z.shape[1]
    bn = min(block_n, max(8, N))
    pad = (-N) % bn
    if pad:
        Xp = jnp.pad(X, ((0, pad), (0, 0)))
        Zp = jnp.pad(Z, ((0, pad), (0, 0)))
        # padded rows: force "keep current bit (0)" by +inf logit-uniforms
        up = jnp.pad(u_logit, ((0, pad), (0, 0)), constant_values=1e30)
    else:
        Xp, Zp, up = X, Z, u_logit
    out = gibbs_flip_pallas(
        Xp, Zp, A, logit_pi, active, up, inv2s2,
        block_n=bn, interpret=interpret,
    )
    return out[:N].astype(Z.dtype)


def gibbs_flip(
    X: Array,
    Z: Array,
    A: Array,
    pi: Array,
    active: Array,
    sigma_x: Array,
    key: Array,
    block_n: int = DEFAULT_BLOCK_N,
) -> Array:
    """Drop-in replacement for sweeps.uncollapsed_sweep (backend='pallas')."""
    u = _logit(jax.random.uniform(key, Z.shape, dtype=jnp.float32))
    inv2s2 = 0.5 / (sigma_x.astype(jnp.float32) ** 2)
    return gibbs_flip_core(
        X, Z, A, _logit(pi), active, u, inv2s2,
        block_n=block_n, interpret=default_interpret(),
    )
