"""JAX-version compatibility shims (DESIGN.md §6).

The production sharding path was written against the modern mesh API
(``jax.sharding.AxisType``, ``jax.set_mesh``, ``jax.shard_map`` with
``check_vma``). The installed floor is JAX 0.4.37, where none of those
exist: meshes have no axis types, there is no global mesh setter, and
shard_map lives in ``jax.experimental.shard_map`` with the older
``check_rep`` knob. Every mesh-construction / mesh-context /
shard_map call site in the repo goes through this module so one
codebase runs on both — never import ``AxisType`` / ``set_mesh`` /
``shard_map`` from ``jax`` directly.

All shims are semantic no-ops on the old API:

* ``AxisType.Auto`` is the default (and only) behavior of a 0.4.x mesh.
* ``set_mesh`` only matters for the implicit-mesh jit path; our code
  always passes explicit ``NamedSharding``s (which carry their mesh),
  so a null context is correct.
* ``check_vma=False`` maps to ``check_rep=False`` — same meaning
  (skip the replication/varying-manual-axes check), renamed upstream.
"""
from __future__ import annotations

import enum
from typing import Any, Sequence

import jax

try:  # jax >= 0.6: meshes carry explicit/auto/manual axis types
    from jax.sharding import AxisType  # type: ignore[attr-defined]

    HAS_AXIS_TYPE = True
except ImportError:
    HAS_AXIS_TYPE = False

    class AxisType(enum.Enum):  # type: ignore[no-redef]
        """Stand-in for ``jax.sharding.AxisType`` on 0.4.x (all-Auto)."""

        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


def make_mesh(
    axis_shapes: Sequence[int],
    axis_names: Sequence[str],
    *,
    axis_types: tuple | None = None,
    devices: Sequence | None = None,
):
    """``jax.make_mesh`` that drops ``axis_types`` where unsupported."""
    kw: dict[str, Any] = {}
    if devices is not None:
        kw["devices"] = devices
    if axis_types is not None and HAS_AXIS_TYPE:
        try:
            return jax.make_mesh(
                axis_shapes, axis_names, axis_types=axis_types, **kw
            )
        except TypeError:  # make_mesh exists but predates axis_types
            pass
    return jax.make_mesh(axis_shapes, axis_names, **kw)


def set_mesh(mesh):
    """Context manager equivalent of ``jax.set_mesh`` on any version.

    On 0.4.x the legacy ``with mesh:`` context sets the ambient
    (thread-resource) mesh, which is what bare-PartitionSpec
    ``with_sharding_constraint`` calls resolve against — the same role
    ``jax.set_mesh`` plays on the modern API.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)  # type: ignore[attr-defined]
    return mesh  # jax.sharding.Mesh is itself a context manager


def get_abstract_mesh():
    """The ambient mesh set by ``set_mesh``, or None when unset/empty."""
    if hasattr(jax.sharding, "get_abstract_mesh"):
        mesh = jax.sharding.get_abstract_mesh()  # type: ignore[attr-defined]
        return mesh if mesh is not None and mesh.axis_names else None
    from jax.interpreters import pxla

    mesh = pxla.thread_resources.env.physical_mesh
    return mesh if mesh is not None and mesh.axis_names else None


def shard_map(f, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` with the ``check_vma``/``check_rep`` rename folded in."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma,
        )
    from jax.experimental import shard_map as _sm

    return _sm.shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_vma,
    )


def axis_index(axis_names):
    """``jax.lax.axis_index`` accepting a 1-tuple on versions that only
    take a bare name."""
    if not isinstance(axis_names, str) and len(axis_names) == 1:
        return jax.lax.axis_index(axis_names[0])
    return jax.lax.axis_index(axis_names)
