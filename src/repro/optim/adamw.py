"""AdamW + global-norm clipping in pure JAX (optax is not available offline).

Optimizer state mirrors the parameter pytree (same sharding — the launcher
pjit's it with the param pspecs), plus a scalar step counter.

``grad_compress`` hook: when set to "int8", gradients are stochastically
quantized to int8 with per-leaf scales before the (data-parallel) all-reduce
implied by pjit, and dequantized after — a distributed-optimization trick for
bandwidth-bound meshes (EXPERIMENTS.md §Perf discusses when it pays off).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

Array = jax.Array


def global_norm(tree) -> Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def quantize_int8(g: Array, key: Array) -> tuple[Array, Array]:
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-30
    noise = jax.random.uniform(key, g.shape, jnp.float32) - 0.5
    q = jnp.clip(jnp.round(g / scale + noise), -127, 127).astype(jnp.int8)
    return q, scale


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[Array], Array] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    grad_compress: str = "none"   # "none" | "int8"

    def init(self, params):
        zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return {
            "m": zeros,
            "v": jax.tree.map(jnp.zeros_like, zeros),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(self, params, grads, state):
        step = state["step"] + 1
        if self.grad_compress == "int8":
            key = jax.random.fold_in(jax.random.key(17), step)
            leaves, treedef = jax.tree.flatten(grads)
            qs = []
            for i, g in enumerate(leaves):
                q, s = quantize_int8(
                    g.astype(jnp.float32), jax.random.fold_in(key, i)
                )
                qs.append(q.astype(jnp.float32) * s)
            grads = jax.tree.unflatten(treedef, qs)

        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

        lr = self.lr(step) if callable(self.lr) else self.lr
        b1, b2 = self.b1, self.b2
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, m_, v_):
            u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + self.eps)
            u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        params = jax.tree.map(upd, params, m, v)
        return params, {"m": m, "v": v, "step": step}


def sgd_momentum(lr: float = 0.1, momentum: float = 0.9):
    @dataclasses.dataclass(frozen=True)
    class _SGD:
        def init(self, params):
            return {
                "mom": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
                "step": jnp.zeros((), jnp.int32),
            }

        def update(self, params, grads, state):
            mom = jax.tree.map(
                lambda m, g: momentum * m + g.astype(jnp.float32),
                state["mom"], grads,
            )
            params = jax.tree.map(
                lambda p, m: (p.astype(jnp.float32) - lr * m).astype(p.dtype),
                params, mom,
            )
            return params, {"mom": mom, "step": state["step"] + 1}

    return _SGD()
