from .adamw import AdamW, sgd_momentum
from .schedule import cosine_schedule, linear_warmup

__all__ = ["AdamW", "sgd_momentum", "cosine_schedule", "linear_warmup"]
