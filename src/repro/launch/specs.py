"""Abstract input/param/cache specs per (arch x shape) cell — ShapeDtypeStruct
stand-ins only, no device allocation (the dry-run contract)."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig, ShapeConfig
from repro.models import init_caches, init_model

Struct = jax.ShapeDtypeStruct


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict[str, Struct]:
    """Model inputs for one step, as ShapeDtypeStructs."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    act = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    if shape.mode == "train":
        batch = {
            "tokens": Struct((B, S), i32),
            "labels": Struct((B, S), i32),
        }
    elif shape.mode == "prefill":
        batch = {"tokens": Struct((B, S), i32)}
    else:  # decode: one new token against an S-long cache
        batch = {"tokens": Struct((B, 1), i32)}
    if cfg.family == "encdec":
        if shape.mode == "decode":
            # encoder ran at prefill; serving passes its output
            batch["enc_out"] = Struct((B, cfg.enc_seq, cfg.d_model), act)
        else:
            batch["frames"] = Struct((B, cfg.enc_seq, cfg.d_model), act)
    if cfg.family == "vlm" and shape.mode != "decode":
        batch["patches"] = Struct((B, cfg.stub_tokens, cfg.d_model), act)
    return batch


def abstract_model(cfg: ModelConfig, *, serve: bool = False):
    """(param structs, pspec tree) without allocating anything."""
    holder: dict[str, Any] = {}

    def build(key):
        p, s = init_model(key, cfg)
        holder["specs"] = s
        return p

    pstruct = jax.eval_shape(build, jax.random.key(0))
    if serve:  # deployed weights are bf16
        pstruct = jax.tree.map(
            lambda s: Struct(s.shape, jnp.bfloat16)
            if s.dtype == jnp.float32 else s,
            pstruct,
        )
    return pstruct, holder["specs"]


def abstract_caches(cfg: ModelConfig, B: int, S: int):
    return jax.eval_shape(lambda: init_caches(cfg, B, S))


def param_bytes(pstruct, bytes_per_el: int = 2) -> int:
    return sum(
        int(jnp.prod(jnp.array(x.shape))) * bytes_per_el
        for x in jax.tree.leaves(pstruct)
    )
