"""Production mesh entry point (launch-facing re-export).

``make_production_mesh(multi_pod=False)`` -> (16, 16) ("data", "model");
``multi_pod=True`` -> (2, 16, 16) ("pod", "data", "model"). A function, not a
module-level constant: importing this module never touches jax device state.
"""
from repro.parallel.mesh import make_production_mesh, mesh_axes

__all__ = ["make_production_mesh", "mesh_axes"]
