import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves the distribution config is coherent on the
production mesh without hardware: jit(step).lower(**ShapeDtypeStructs)
.compile() must succeed; we record memory_analysis, cost_analysis, and the
collective bytes parsed from the partitioned HLO into
artifacts/dryrun/<arch>__<shape>__<mesh>.json (incremental: existing cells
are skipped unless --force).

Usage:
  python -m repro.launch.dryrun --arch granite-3-8b --shape train_4k --mesh pod1
  python -m repro.launch.dryrun --all [--mesh pod1|pod2|both]
"""
import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro import compat

from repro.configs import ALL_SHAPES, ARCH_IDS, get_config, shape_applicable
from repro.launch.specs import (
    abstract_caches,
    abstract_model,
    input_specs,
    param_bytes,
)
from repro.models import make_decode_step, make_prefill_step, make_train_step
from repro.optim import AdamW
from repro.parallel.mesh import (
    act_specs,
    batch_specs,
    cache_specs,
    make_production_mesh,
    named,
    resolve_param_specs,
)

ARTIFACTS = os.path.join(os.path.dirname(__file__), "../../../artifacts/dryrun")

COLLECTIVE_OPS = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(tok: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(tok):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-device bytes moved by collectives, from the partitioned HLO.

    We sum RESULT shapes: for all-gather that is the gathered (full) size,
    for all-reduce the reduced operand size, for reduce-scatter the shard —
    a uniform, slightly conservative proxy for bytes-on-the-wire.
    """
    out: dict[str, int] = {k: 0 for k in COLLECTIVE_OPS}
    out["counts"] = {k: 0 for k in COLLECTIVE_OPS}  # type: ignore[assignment]
    for line in hlo_text.splitlines():
        ls = line.strip()
        if "=" not in ls:
            continue
        for op in COLLECTIVE_OPS:
            # match '= <shape> op(' including fused variants like
            # 'all-reduce-start('
            m = re.search(rf"= (.*?) {op}(?:-start)?\(", ls)
            if m:
                out[op] += _shape_bytes(m.group(1))
                out["counts"][op] += 1  # type: ignore[index]
                break
    out["total"] = sum(out[k] for k in COLLECTIVE_OPS)
    return out


def cost_dict(compiled) -> dict:
    """``compiled.cost_analysis()`` normalized across JAX versions: 0.4.x
    returns a list with one dict per device program, newer versions the
    dict itself."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}


def build_step(cfg, shape, mesh, force_param_bytes: int | None = None):
    """Returns (jitted_fn, example_args as ShapeDtypeStructs w/ shardings)."""
    serve = shape.mode != "train"
    pstruct, pspecs = abstract_model(cfg, serve=serve)
    pbytes = force_param_bytes or param_bytes(pstruct, 2)
    pspec_r = resolve_param_specs(
        pspecs, pstruct, mesh,
        mode="train" if not serve else "serve",
        param_bytes=pbytes,
    )
    specs = act_specs(
        mesh, seq_len=shape.seq_len, batch=shape.global_batch,
        mode=shape.mode, d_ff=max(cfg.d_ff, 2 * (cfg.d_ff_expert or 0)),
    )
    batch = input_specs(cfg, shape)
    bspec = batch_specs(batch, mesh)

    p_sh = named(mesh, pspec_r)
    b_sh = named(mesh, bspec)

    if shape.mode == "train":
        opt = AdamW(lr=1e-4)
        ostruct = jax.eval_shape(opt.init, pstruct)
        ospec = {
            "m": pspec_r,
            "v": pspec_r,
            "step": jax.sharding.PartitionSpec(),
        }
        o_sh = named(mesh, ospec)
        step = make_train_step(cfg, opt, specs)
        fn = jax.jit(
            step,
            in_shardings=(p_sh, o_sh, b_sh),
            out_shardings=(p_sh, o_sh, None),
            donate_argnums=(0, 1),
        )
        args = (pstruct, ostruct, batch)
    elif shape.mode == "prefill":
        step = make_prefill_step(cfg, specs)
        fn = jax.jit(step, in_shardings=(p_sh, b_sh))
        args = (pstruct, batch)
    else:
        cstruct = abstract_caches(cfg, shape.global_batch, shape.seq_len)
        cspec = cache_specs(cstruct, mesh)
        c_sh = named(mesh, cspec)
        step = make_decode_step(cfg, specs)
        fn = jax.jit(
            step,
            in_shardings=(p_sh, b_sh, c_sh),
            out_shardings=(None, c_sh),
            donate_argnums=(2,),
        )
        args = (pstruct, batch, cstruct)
    return fn, args


def run_cell(arch: str, shape, mesh_name: str, force: bool = False) -> dict:
    os.makedirs(ARTIFACTS, exist_ok=True)
    out_path = os.path.join(
        ARTIFACTS, f"{arch}__{shape.name}__{mesh_name}.json"
    )
    if os.path.exists(out_path) and not force:
        with open(out_path) as fh:
            return json.load(fh)

    cfg = get_config(arch)
    ok, why = shape_applicable(cfg, shape)
    rec: dict = {
        "arch": arch, "shape": shape.name, "mesh": mesh_name,
        "mode": shape.mode, "seq_len": shape.seq_len,
        "global_batch": shape.global_batch,
    }
    if not ok:
        rec.update(status="skipped", reason=why)
        with open(out_path, "w") as fh:
            json.dump(rec, fh, indent=1)
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_name == "pod2"))
    t0 = time.time()
    try:
        with compat.set_mesh(mesh):
            fn, args = build_step(cfg, shape, mesh)
            lowered = fn.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost = cost_dict(compiled)
            hlo = compiled.as_text()
        coll = collective_bytes(hlo)
        rec.update(
            status="ok",
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            flops=float(cost.get("flops", -1.0)) if cost else -1.0,
            bytes_accessed=float(cost.get("bytes accessed", -1.0))
            if cost else -1.0,
            collectives=coll,
            memory={
                k: int(getattr(mem, k))
                for k in (
                    "argument_size_in_bytes",
                    "output_size_in_bytes",
                    "temp_size_in_bytes",
                    "generated_code_size_in_bytes",
                )
                if mem is not None and hasattr(mem, k)
            },
            hlo_bytes=len(hlo),
        )
    except Exception as e:  # a failing cell is a bug — record it loudly
        rec.update(
            status="error",
            error=f"{type(e).__name__}: {e}",
            traceback=traceback.format_exc()[-4000:],
        )
    with open(out_path, "w") as fh:
        json.dump(rec, fh, indent=1)
    return rec


def run_ibp_cell(mesh_name: str, *, N: int = 1 << 20, D: int = 36,
                 K_max: int = 64, K_tail: int = 8, L: int = 5,
                 force: bool = False, tag: str = "mcmc_1m",
                 sync: str = "staged") -> dict:
    """Lower the paper's hybrid sampler itself on the production mesh: 2^20
    observations sharded over every chip (the paper's P processors = 256/512),
    Cambridge dimensionality. This is the 'most representative of the paper's
    technique' roofline/hillclimb cell (§Perf cell 3)."""
    import numpy as np
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    from repro.core.ibp import IBPHypers, SamplerSpec, build_hybrid_fns

    os.makedirs(ARTIFACTS, exist_ok=True)
    name = f"ibp-hybrid__{tag}" + ("" if sync == "staged" else f"-{sync}")
    out_path = os.path.join(ARTIFACTS, f"{name}__{mesh_name}.json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as fh:
            return json.load(fh)

    mesh = make_production_mesh(multi_pod=(mesh_name == "pod2"))
    axes = tuple(mesh.axis_names)            # every chip is one processor p
    P_ = int(np.prod([mesh.shape[a] for a in axes]))
    rec: dict = {
        "arch": "ibp-hybrid", "shape": tag, "mesh": mesh_name,
        "mode": "mcmc", "seq_len": D, "global_batch": N, "sync": sync,
        "P": P_, "K_max": K_max, "K_tail": K_tail, "L": L,
    }
    t0 = time.time()
    try:
        with compat.set_mesh(mesh):
            # every production mesh axis is a data axis here (flattened
            # into the paper's P processors); no chain axis in this cell
            spec = SamplerSpec(P=P_, L=L, K_max=K_max, K_tail=K_tail,
                               data="shardmap", sync=sync)
            step = build_hybrid_fns(spec, IBPHypers(), N_global=N,
                                    mesh=mesh, data_axes=axes).step
            f32 = jnp.float32
            row_sh = NamedSharding(mesh, P(axes))
            rep = NamedSharding(mesh, P())

            def rs(shape):
                return jax.ShapeDtypeStruct(shape, f32, sharding=row_sh)

            from repro.core.ibp.hybrid import HybridGlobal
            gs = HybridGlobal(
                A=jax.ShapeDtypeStruct((K_max, D), f32, sharding=rep),
                pi=jax.ShapeDtypeStruct((K_max,), f32, sharding=rep),
                active=jax.ShapeDtypeStruct((K_max,), f32, sharding=rep),
                alpha=jax.ShapeDtypeStruct((), f32, sharding=rep),
                sigma_x=jax.ShapeDtypeStruct((), f32, sharding=rep),
                sigma_a=jax.ShapeDtypeStruct((), f32, sharding=rep),
                key=jax.ShapeDtypeStruct(
                    (), jax.random.key(0).dtype, sharding=rep),
                p_prime=jax.ShapeDtypeStruct((), jnp.int32, sharding=rep),
                it=jax.ShapeDtypeStruct((), jnp.int32, sharding=rep),
                overflow=jax.ShapeDtypeStruct((), jnp.int32, sharding=rep),
                tail_sat=jax.ShapeDtypeStruct((), jnp.int32, sharding=rep),
            )
            args = (rs((N, D)), gs, rs((N, K_max)), rs((N, K_tail)),
                    jax.ShapeDtypeStruct((P_, K_tail), f32, sharding=row_sh))
            lowered = step.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost = cost_dict(compiled)
            hlo = compiled.as_text()
        coll = collective_bytes(hlo)
        rec.update(
            status="ok",
            lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
            flops=float(cost.get("flops", -1.0)) if cost else -1.0,
            bytes_accessed=float(cost.get("bytes accessed", -1.0))
            if cost else -1.0,
            collectives=coll,
            memory={
                k: int(getattr(mem, k))
                for k in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "generated_code_size_in_bytes")
                if mem is not None and hasattr(mem, k)
            },
            hlo_bytes=len(hlo),
        )
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    with open(out_path, "w") as fh:
        json.dump(rec, fh, indent=1)
    return rec


def _probe_depths(cfg) -> tuple[int, int]:
    """Layer counts for the two depth probes (pattern-preserving)."""
    if cfg.family == "hybrid":
        p = len(cfg.rglru_pattern or ("rec", "rec", "attn"))
        return p, 2 * p
    return 1, 2


def run_probe(arch: str, shape, mesh_name: str, force: bool = False) -> dict:
    """Lower reduced-depth variants to measure the per-layer marginal cost.

    XLA-CPU cost_analysis counts a while-loop body once regardless of trip
    count, so full-depth HLO flops/bytes under scan-over-layers are
    undercounted. The roofline reader extrapolates:
        total ~= probe(L1) + (L - L1) / (L2 - L1) * (probe(L2) - probe(L1)).
    Probes run with the FULL model's param-byte budget so the serve
    FSDP decision (and hence the collective pattern) matches the real cell.
    """
    import dataclasses

    os.makedirs(ARTIFACTS, exist_ok=True)
    out_path = os.path.join(
        ARTIFACTS, f"probe__{arch}__{shape.name}__{mesh_name}.json"
    )
    if os.path.exists(out_path) and not force:
        with open(out_path) as fh:
            return json.load(fh)

    cfg = get_config(arch)
    ok, why = shape_applicable(cfg, shape)
    rec: dict = {"arch": arch, "shape": shape.name, "mesh": mesh_name}
    if not ok:
        rec.update(status="skipped", reason=why)
        with open(out_path, "w") as fh:
            json.dump(rec, fh, indent=1)
        return rec

    pstruct, _ = abstract_model(cfg, serve=shape.mode != "train")
    full_pbytes = param_bytes(pstruct, 2)
    mesh = make_production_mesh(multi_pod=(mesh_name == "pod2"))
    L1, L2 = _probe_depths(cfg)
    probes = {}
    try:
        for L in (L1, L2):
            # unroll: XLA cost_analysis counts a lax.scan body ONCE regardless
            # of trip count, so probes must unroll for the L2-L1 marginal to be
            # the true per-layer cost (roofline extrapolation depends on it)
            sub = {"n_layers": L, "unroll_layers": True}
            if cfg.family == "encdec":
                sub["n_enc_layers"] = L
            cfg_l = dataclasses.replace(cfg, **sub)
            with compat.set_mesh(mesh):
                fn, args = build_step(
                    cfg_l, shape, mesh, force_param_bytes=full_pbytes
                )
                compiled = fn.lower(*args).compile()
                cost = cost_dict(compiled)
                hlo = compiled.as_text()
            coll = collective_bytes(hlo)
            probes[str(L)] = {
                "flops": float(cost.get("flops", -1.0)),
                "bytes_accessed": float(cost.get("bytes accessed", -1.0)),
                "collective_total": coll["total"],
            }
        rec.update(status="ok", L1=L1, L2=L2, probes=probes)
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    with open(out_path, "w") as fh:
        json.dump(rec, fh, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=[s.name for s in ALL_SHAPES])
    ap.add_argument("--mesh", choices=["pod1", "pod2", "both"], default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--probe", action="store_true",
                    help="lower reduced-depth variants for roofline "
                         "extrapolation instead of the full cells")
    ap.add_argument("--ibp", action="store_true",
                    help="lower the IBP hybrid-sampler cell (2^20 rows over "
                         "all chips) instead of LM cells")
    ap.add_argument("--sync", choices=["staged", "fused"], default="staged")
    args = ap.parse_args()

    meshes = ["pod1", "pod2"] if args.mesh == "both" else [args.mesh]

    if args.ibp:
        bad = 0
        for mesh_name in meshes:
            rec = run_ibp_cell(mesh_name, force=args.force, sync=args.sync)
            extra = ""
            if rec["status"] == "ok":
                c = rec["collectives"]
                extra = (f"compile={rec['compile_s']}s "
                         f"AR_count={c['counts']['all-reduce']} "
                         f"coll={c['total'] / 2**20:.2f}MiB "
                         f"flops={rec['flops']:.3g}")
            elif rec["status"] == "error":
                extra = rec["error"][:200]
            print(f"[{rec['status']:7s}] ibp-hybrid ({args.sync:6s}) "
                  f"{mesh_name} {extra}", flush=True)
            bad += rec["status"] == "error"
        return 1 if bad else 0
    archs = ARCH_IDS if args.all or not args.arch else [args.arch]
    shapes = (
        ALL_SHAPES
        if args.all or not args.shape
        else [s for s in ALL_SHAPES if s.name == args.shape]
    )

    n_ok = n_skip = n_err = 0
    for arch in archs:
        for shape in shapes:
            for mesh_name in meshes:
                if args.probe:
                    rec = run_probe(arch, shape, mesh_name, force=args.force)
                    print(f"[{rec['status']:7s}] probe {arch:24s} "
                          f"{shape.name:12s} {mesh_name}", flush=True)
                    n_ok += rec["status"] == "ok"
                    n_skip += rec["status"] == "skipped"
                    n_err += rec["status"] == "error"
                    continue
                rec = run_cell(arch, shape, mesh_name, force=args.force)
                tag = rec["status"]
                n_ok += tag == "ok"
                n_skip += tag == "skipped"
                n_err += tag == "error"
                extra = ""
                if tag == "ok":
                    mem_gb = rec["memory"].get("temp_size_in_bytes", 0) / 2**30
                    extra = (
                        f"compile={rec['compile_s']}s flops/dev="
                        f"{rec['flops']:.3g} coll/dev="
                        f"{rec['collectives']['total'] / 2**20:.1f}MiB "
                        f"temp={mem_gb:.2f}GiB"
                    )
                elif tag == "error":
                    extra = rec["error"][:160]
                print(f"[{tag:7s}] {arch:24s} {shape.name:12s} {mesh_name} {extra}",
                      flush=True)
    print(f"\nok={n_ok} skipped={n_skip} error={n_err}")
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
