"""Posterior-predictive serving loop over a harvested ``SampleBank``.

The inference counterpart of ``launch/mcmc.py`` (DESIGN.md §15): load a
bank harvested with ``--harvest-every``, then run a microbatching
request loop —

    queue → pad-to-bucket → one jitted (S × B)-batched score → respond

Requests of ragged sizes are coalesced up to ``--batch`` rows, padded to
a power-of-two row bucket (8, 16, ..., batch) so the jit cache stays
O(log batch), scored in ONE dispatch across the whole ensemble, and
answered per-request. Throughput (rows/s) and latency percentiles —
each coalesced request is charged its microbatch's FULL dispatch wall
time; queueing delay before the dispatch is not modeled — are reported
and merged into the repo-root ``BENCH_<date>.json`` under the
``"serving_loop"`` key.

Usage:
  # fit + harvest, then serve the bank
  python -m repro.launch.mcmc --N 500 --iters 400 --harvest-every 10 \\
      --ckpt-dir artifacts/ckpt/mcmc
  python -m repro.launch.serve_ibp --bank artifacts/ckpt/mcmc/bank.npz \\
      --op loglik --requests 64

Knobs:

  --bank PATH          SampleBank npz (from --harvest-every / save_bank)
  --op loglik|anomaly|encode|impute
                       which predictive op the loop serves
  --batch INT          microbatch row budget per dispatch (default 256)
  --requests INT       synthetic requests to generate (smoke/bench mode)
  --max-request INT    max rows per synthetic request
  --missing FLOAT      missing-dim fraction for --op impute masks
  --n-sweeps INT       Gibbs sweeps per sample inside the scorer
  --seed INT           request-stream seed
  --bench-json PATH    merge the serving section here (default "none" —
                       ordinary serving runs leave the tracked perf
                       trajectory untouched; "" = repo-root
                       BENCH_<date>.json to record a trajectory point)
  --smoke              tiny sizes + sanity assertions (CI fast gate)
"""
from __future__ import annotations

import argparse
import datetime
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ibp import predict
from repro.core.ibp import math as ibm

REPO_ROOT = os.path.dirname(
    os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__)))))

OPS = ("loglik", "anomaly", "encode", "impute")


def row_buckets(batch: int) -> tuple[int, ...]:
    """Power-of-two row-count ladder 8, 16, ..., batch — the §14 bucket
    ladder applied to the batch row axis (one policy, one helper)."""
    return ibm.live_buckets(batch)


def pad_to_bucket(X: np.ndarray, buckets: tuple[int, ...]) -> np.ndarray:
    """Zero-pad rows up to the smallest bucket that fits (zero rows are
    scored too — callers slice the first len(X) results)."""
    n = X.shape[0]
    B = ibm.pick_bucket(buckets, n, 0)
    if B == n:
        return X
    return np.concatenate([X, np.zeros((B - n, X.shape[1]), X.dtype)])


def make_op(bank, op: str, n_sweeps: int):
    """The jitted scorer for one op: fn(X_padded, mask, key) -> host array.

    Every op is one (S samples × B rows)-batched dispatch; per-request
    results are sliced on the host after the fetch. Only ``impute``
    consumes the request masks — the other ops treat serving rows as
    fully observed and pass ``mask=None`` so they run predict's unmasked
    fast path (the trace-time branch §15 optimizes; the perf gate in
    benchmarks/run.py times exactly this path)."""
    if op == "loglik":
        return lambda X, m, k: predict.predictive_loglik(
            bank, X, k, n_sweeps=n_sweeps)
    if op == "anomaly":
        return lambda X, m, k: predict.anomaly_score(
            bank, X, k, n_sweeps=n_sweeps)
    if op == "encode":
        return lambda X, m, k: predict.encode(
            bank, X, k, n_sweeps=n_sweeps)
    if op == "impute":
        return lambda X, m, k: predict.impute(
            bank, X, m, k, n_sweeps=n_sweeps)
    raise ValueError(f"op={op!r} not in {OPS}")


def synth_requests(n_requests: int, max_rows: int, D: int, seed: int,
                   missing: float):
    """Synthetic request stream: Cambridge held-out-like rows in ragged
    request sizes, with a per-request observation mask."""
    from repro.data import cambridge_data

    rng = np.random.default_rng(seed)
    N = max(n_requests * max_rows, 64)
    X, _, _ = cambridge_data(N=N, sigma_n=0.5, seed=seed + 1)
    if X.shape[1] != D:
        # bank trained on different D (synthetic bench banks): plain noise
        X = rng.normal(size=(N, D)).astype(np.float32)
    reqs, at = [], 0
    for _ in range(n_requests):
        n = int(rng.integers(1, max_rows + 1))
        rows = X[at:at + n]
        at += n
        mask = (rng.random(rows.shape) >= missing).astype(np.float32)
        mask[mask.sum(axis=1) < 1.0, 0] = 1.0  # at least one observed dim
        reqs.append((rows.astype(np.float32), mask))
    return reqs


def serve(bank, reqs, op: str, batch: int, n_sweeps: int, seed: int):
    """The microbatching loop. Returns (responses, stats dict)."""
    buckets = row_buckets(batch)
    fn = make_op(bank, op, n_sweeps)
    key = jax.random.key(seed)

    # warm the jit cache at every bucket so steady-state latency is
    # measured, not compilation (serving contract: compile at startup)
    D = bank.D
    t0 = time.time()
    for B in buckets:
        z = jnp.zeros((B, D), jnp.float32)
        jax.block_until_ready(fn(z, jnp.ones_like(z), key))
    t_warm = time.time() - t0

    # oversized requests are split into <= batch fragments up front; the
    # fragments keep their request index so the per-request response is
    # reassembled at the end — one response per request, always, and the
    # caller's ``reqs`` list is never mutated
    frags = []
    for ri, (rows, mask) in enumerate(reqs):
        for at in range(0, rows.shape[0], batch):
            frags.append((ri, rows[at:at + batch], mask[at:at + batch]))

    parts: dict[int, list] = {ri: [] for ri in range(len(reqs))}
    req_lat_us = [0.0] * len(reqs)
    rows_done = 0
    t0 = time.time()
    i = 0
    while i < len(frags):
        # coalesce queued fragments up to the batch row budget
        take, n_rows = [], 0
        while i < len(frags) and n_rows + frags[i][1].shape[0] <= batch:
            take.append(frags[i])
            n_rows += frags[i][1].shape[0]
            i += 1
        Xb = np.concatenate([r for _, r, _ in take])
        Mb = np.concatenate([m for _, _, m in take])
        t_req = time.time()
        Xp = pad_to_bucket(Xb, buckets)
        Mp = pad_to_bucket(Mb, buckets)
        key, kreq = jax.random.split(key)
        out = np.asarray(jax.block_until_ready(fn(Xp, Mp, kreq)))
        # respond: slice the batched result back per fragment
        out = out[..., :n_rows, :] if op == "encode" else out[:n_rows]
        at = 0
        for ri, rows, _ in take:
            n = rows.shape[0]
            parts[ri].append(out[..., at:at + n, :] if op == "encode"
                             else out[at:at + n])
            at += n
        dt = time.time() - t_req
        # every request in the microbatch waits for the WHOLE dispatch:
        # that full wall time is its latency (coalescing buys throughput,
        # not per-request speed — the percentiles must say so). A request
        # split across several microbatches accumulates EACH of its
        # dispatches' wall time: its fragments run in consecutive
        # batches, so the sum is its true completion latency.
        for ri in {ri for ri, _, _ in take}:
            req_lat_us[ri] += dt * 1e6
        rows_done += n_rows
    t_total = time.time() - t0

    def assemble(p):
        if len(p) == 1:
            return p[0]
        if not p:  # zero-row request: well-shaped empty response
            if op == "encode":
                return np.zeros((bank.S, 0, bank.K), np.float32)
            return np.zeros((0, D) if op == "impute" else (0,), np.float32)
        return np.concatenate(p, axis=-2 if op == "encode" else 0)

    responses = [assemble(parts[ri]) for ri in range(len(reqs))]
    lat = np.asarray(sorted(req_lat_us)) if req_lat_us else np.zeros(1)
    stats = {
        "op": op, "S": bank.S, "K": bank.K, "D": bank.D,
        "batch": batch, "n_sweeps": n_sweeps,
        "requests": len(reqs), "rows": rows_done,
        "rows_per_s": rows_done / max(t_total, 1e-9),
        "latency_p50_us": float(lat[len(lat) // 2]),
        "latency_p95_us": float(lat[min(len(lat) - 1,
                                        int(0.95 * len(lat)))]),
        "warmup_s": t_warm,
    }
    return responses, stats


def merge_bench_json(stats: dict, path: str) -> str:
    """Append the serving stats into BENCH_<date>.json via the shared
    tolerant atomic merge (``checkpoint.update_json`` — the same
    two-writer contract ``benchmarks/run.py`` uses)."""
    from repro.checkpoint import update_json

    if not path:
        path = os.path.join(
            REPO_ROOT, f"BENCH_{datetime.date.today().isoformat()}.json")

    def add(payload: dict) -> dict:
        payload.setdefault("serving_loop", []).append(stats)
        return payload

    return update_json(path, add)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--bank", required=True,
                    help="SampleBank npz (launch.mcmc --harvest-every)")
    ap.add_argument("--op", default="loglik", choices=OPS)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--max-request", type=int, default=48)
    ap.add_argument("--missing", type=float, default=0.25)
    ap.add_argument("--n-sweeps", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--bench-json", default="none",
                    help='where to merge the serving_loop stats: "none" '
                         '(default — ordinary serving runs must not '
                         'mutate the tracked perf trajectory), "" = '
                         'repo-root BENCH_<date>.json (recording a '
                         'trajectory point), or an explicit path')
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes + sanity assertions (CI fast gate)")
    args = ap.parse_args(argv)

    if args.smoke:
        args.requests = min(args.requests, 8)
        args.max_request = min(args.max_request, 12)
        args.batch = min(args.batch, 32)

    bank = predict.SampleBank.load(args.bank)
    print(f"bank: S={bank.S} samples, K={bank.K} features (bucket-"
          f"packed), D={bank.D}, chains={sorted(set(np.asarray(bank.chain).tolist()))}")
    reqs = synth_requests(args.requests, args.max_request, bank.D,
                          args.seed, args.missing if args.op == "impute"
                          else 0.0)
    responses, stats = serve(bank, reqs, args.op, args.batch,
                             args.n_sweeps, args.seed)
    print(f"op={stats['op']}: {stats['rows']} rows / "
          f"{stats['requests']} requests -> "
          f"{stats['rows_per_s']:.0f} rows/s, "
          f"p50={stats['latency_p50_us']:.0f}us "
          f"p95={stats['latency_p95_us']:.0f}us "
          f"(warmup {stats['warmup_s']:.1f}s)")

    if args.smoke:
        assert len(responses) == len(reqs), "lost responses"
        for (rows, _), resp in zip(reqs, responses):
            n = rows.shape[0]
            got = resp.shape[-2] if args.op == "encode" else resp.shape[0]
            assert got == n, f"response rows {got} != request rows {n}"
            assert np.all(np.isfinite(np.asarray(resp))), "non-finite scores"
        print("smoke OK")

    if args.bench_json != "none":
        path = merge_bench_json(stats, args.bench_json)
        print(f"serving section -> {path}")


if __name__ == "__main__":
    main()
