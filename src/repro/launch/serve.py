"""Batched serving launcher: prefill + decode loop with KV caches.

Usage (CPU demo):
  python -m repro.launch.serve --arch smollm-135m --smoke --batch 4 --new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import (
    init_caches,
    init_model,
    make_decode_step,
)
from repro.models.transformer import model_apply


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    params, _ = init_model(jax.random.key(args.seed), cfg)
    B, S = args.batch, args.prompt_len
    prompt = jax.random.randint(
        jax.random.key(1), (B, S), 0, cfg.vocab, jnp.int32
    )

    # prefill: run the prompt through the decode path to warm the cache
    # (single-step decode per position keeps one code path; batched prefill
    # is exercised by the dry-run prefill cells)
    caches = init_caches(cfg, B, S + args.new)
    decode = jax.jit(make_decode_step(cfg))
    extras = {}
    if cfg.family == "encdec":
        extras["enc_out"] = jnp.zeros((B, cfg.enc_seq, cfg.d_model),
                                      jnp.float32)
    t0 = time.time()
    tok = prompt[:, :1]
    out = [tok]
    for i in range(S + args.new - 1):
        nxt, caches = decode(params, {"tokens": tok, **extras}, caches)
        tok = jnp.where(i + 1 < S, prompt[:, i + 1:i + 2], nxt[:, None])
        out.append(tok)
    seq = jnp.concatenate(out, axis=1)
    dt = time.time() - t0
    print(f"generated {B}x{args.new} tokens in {dt:.2f}s "
          f"({B * (S + args.new) / dt:.1f} tok/s inc. prefill)")
    print("sample:", seq[0, -args.new:].tolist())
    return seq


if __name__ == "__main__":
    main()
