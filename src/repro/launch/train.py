"""LM training launcher: real data pipeline + checkpointed train loop.

On this CPU container it is exercised with reduced configs (examples/
train_lm.py); on a TPU mesh the same code path scales to the production mesh
(the dry-run proves the sharded step compiles at 256/512 chips).

Usage:
  python -m repro.launch.train --arch smollm-135m --steps 200 --smoke
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore, save_pytree
from repro.configs import get_config
from repro.data.synthetic_lm import SyntheticLM
from repro.models import init_model, make_train_step
from repro.models.transformer import ActSpecs, pad_vocab
from repro.optim import AdamW, cosine_schedule


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--ckpt-dir", default="artifacts/ckpt/lm")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    params, _ = init_model(jax.random.key(args.seed), cfg)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M")

    opt = AdamW(lr=cosine_schedule(args.lr, args.steps // 10, args.steps))
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(cfg, opt))

    data = SyntheticLM(cfg.vocab, args.seq, args.batch, seed=args.seed)

    start = 0
    restored = restore(args.ckpt_dir, {"p": params, "o": opt_state})
    if restored is not None:
        blob, start = restored
        params, opt_state = blob["p"], blob["o"]
        print(f"resumed from step {start}")

    t0 = time.time()
    losses = []
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        if (step + 1) % args.log_every == 0:
            dt = time.time() - t0
            tok_s = (step + 1 - start) * args.batch * args.seq / dt
            print(
                f"step {step+1:5d} loss={losses[-1]:.4f} "
                f"({tok_s:,.0f} tok/s)", flush=True,
            )
        if (step + 1) % args.ckpt_every == 0 or step == args.steps - 1:
            save_pytree(args.ckpt_dir, {"p": params, "o": opt_state}, step + 1)
    print(f"final loss {losses[-1]:.4f} (start {losses[0]:.4f})")
    return losses


if __name__ == "__main__":
    main()
