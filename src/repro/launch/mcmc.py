"""IBP hybrid-MCMC launcher — the paper's experiment, end to end.

The CLI builds a ``SamplerSpec`` (DESIGN.md §13) and hands it to
``MCMCDriver``; ``--driver`` names a point on the composable
``chains`` x ``data`` parallelism grid.

Usage:
  python -m repro.launch.mcmc --N 1000 --P 5 --iters 1000 --L 5
  python -m repro.launch.mcmc --driver multichain --chains 4   # + R-hat/ESS
  python -m repro.launch.mcmc --driver shardmap --sync fused   # data mesh
  # composed: C chains x P data shards on a 2-D ("chains","data") mesh —
  # on CPU, force C*P host devices first:
  #   XLA_FLAGS=--xla_force_host_platform_device_count=4 \\
  python -m repro.launch.mcmc --driver mesh --chains 2 --P 2

Kernel knobs (all spec-validated; see DESIGN.md §12–§14):

  --backend jnp|pallas            uncollapsed Z sweep implementation
  --collapsed-backend ref|fast|pallas
                                  tail collapsed row step (default fast)
  --chol-refresh INT              fast-path exact-refactor cadence
  --k-live-buckets on|off         occupancy-adaptive packing of the
                                  collapsed carry (default on; off =
                                  the same unified core pinned to the
                                  top bucket B = K_max — bitwise the
                                  historical unpacked carry)
  --K-tail INT                    in-flight tail features on p'
                                  (must be <= K_max)
  --k-tail-grow INT               adaptive K_tail: max automatic tail
                                  doublings at checkpoint boundaries
                                  when tail saturation accrues
                                  (0 = fixed K_tail; ceiling K_max)
  --sync staged|fused             master-sync collective schedule
  --stale-sync INT                bounded-staleness passes (non-exact)

Posterior-predictive harvest (DESIGN.md §15):

  --harvest-every INT             harvest one posterior sample (per chain)
                                  into the SampleBank every this many
                                  iterations (0 = off)
  --harvest-burn FLOAT            fraction of the run discarded before
                                  harvesting starts (default 0.5)
  --bank-path PATH                bank npz (default <ckpt-dir>/bank.npz);
                                  serve it with repro.launch.serve_ibp
"""
from __future__ import annotations

import argparse
import json
import os

from repro.core.ibp import IBPHypers, SamplerSpec
from repro.core.ibp.api import DRIVERS
from repro.core.ibp.collapsed import DEFAULT_REFRESH
from repro.data import cambridge_data, train_eval_split
from repro.runtime import MCMCDriver


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--N", type=int, default=1000)
    ap.add_argument("--P", type=int, default=5)
    ap.add_argument("--iters", type=int, default=1000)
    ap.add_argument("--L", type=int, default=5)
    ap.add_argument("--K-max", type=int, default=32)
    ap.add_argument("--K-tail", type=int, default=8,
                    help="in-flight tail features on shard p' (the "
                         "collapsed-birth truncation; <= K_max)")
    ap.add_argument("--k-tail-grow", type=int, default=0,
                    help="adaptive K_tail: maximum automatic tail "
                         "doublings at checkpoint boundaries when the "
                         "tail-saturation counter (eval record "
                         "'tail_sat') accrues; 0 = fixed K_tail, "
                         "ceiling is K_max (DESIGN.md §12)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--sigma-n", type=float, default=0.5)
    ap.add_argument("--ckpt-dir", default="artifacts/ckpt/mcmc")
    ap.add_argument("--eval-every", type=int, default=20)
    ap.add_argument("--backend", default="jnp", choices=["jnp", "pallas"])
    ap.add_argument("--driver", default="vmap", choices=sorted(DRIVERS),
                    help="parallelism layout: vmap (single device), "
                         "multichain (C chains vmapped), shardmap (P-device "
                         "data mesh), mesh (C chains x P data shards on a "
                         "2-D mesh; needs C*P devices)")
    ap.add_argument("--chains", type=int, default=None,
                    help="chain count for --driver multichain/mesh "
                         "(default 4 / 2); values > 1 require a chainful "
                         "driver")
    ap.add_argument("--sync", default="staged", choices=["staged", "fused"],
                    help="master-sync schedule for --driver shardmap/mesh")
    ap.add_argument("--stale-sync", type=int, default=0,
                    help="bounded-staleness passes per iteration (non-exact)")
    ap.add_argument("--collapsed-backend", default="fast",
                    choices=["ref", "fast", "pallas"],
                    help="tail collapsed row step (default: fast — the "
                         "rank-one Cholesky carry, certified equivalent to "
                         "ref by the PR-2 suite and CI soak). ref keeps the "
                         "fresh O(K^3) factorization per row; pallas adds "
                         "the Pallas bit-flip kernel on top of fast")
    ap.add_argument("--chol-refresh", type=int, default=DEFAULT_REFRESH,
                    help="exact-refactorization cadence of the fast/pallas "
                         "collapsed backend (rows between refreshes)")
    ap.add_argument("--k-live-buckets", default="on", choices=["on", "off"],
                    help="occupancy-adaptive packing of the collapsed "
                         "carry (DESIGN.md §14): on (default) runs the "
                         "fast/pallas carry on the live K+ block (power-"
                         "of-two buckets, G = HH^T carried rank-one); "
                         "off keeps the unpacked K_max carry — exactly "
                         "today's pre-packing behavior")
    ap.add_argument("--harvest-every", type=int, default=0,
                    help="SampleBank harvest cadence in iterations "
                         "(0 = off); chain-batched drivers harvest one "
                         "sample per chain (DESIGN.md §15)")
    ap.add_argument("--harvest-burn", type=float, default=0.5,
                    help="fraction of the run discarded as burn-in "
                         "before harvesting starts")
    ap.add_argument("--bank-path", default="",
                    help="SampleBank npz path (default: "
                         "<ckpt-dir>/bank.npz)")
    ap.add_argument("--out", default="artifacts/mcmc_history.json")
    args = ap.parse_args(argv)

    X, Ztrue, Atrue = cambridge_data(N=args.N, sigma_n=args.sigma_n,
                                     seed=args.seed)
    X_train, X_eval = train_eval_split(X, eval_frac=0.1, seed=args.seed)

    chains, data = DRIVERS[args.driver]
    # explicit --chains passes through so spec validation can reject it
    # loudly under a chainless driver; the default never does
    default_chains = {"multichain": 4, "mesh": 2}.get(args.driver, 1)
    spec = SamplerSpec(
        P=args.P, K_max=args.K_max, K_tail=args.K_tail,
        k_tail_grow=args.k_tail_grow, L=args.L, n_iters=args.iters,
        eval_every=args.eval_every, ckpt_dir=args.ckpt_dir, seed=args.seed,
        backend=args.backend, chains=chains, data=data,
        n_chains=(args.chains if args.chains is not None else default_chains),
        sync=args.sync, stale_sync=args.stale_sync,
        collapsed_backend=args.collapsed_backend,
        chol_refresh=args.chol_refresh,
        k_live_buckets=args.k_live_buckets,
        harvest_every=args.harvest_every,
        harvest_burn=args.harvest_burn,
        bank_path=args.bank_path,
    )
    drv = MCMCDriver(X_train, spec, IBPHypers(), X_eval=X_eval)

    def show(r):
        line = (
            f"it={r['it']:5d} t={r['t']:7.1f}s K+={r['K']:4.1f} "
            f"alpha={r['alpha']:.2f} sx={r['sigma_x']:.3f} "
            f"ll_eval={r.get('joint_ll_eval', float('nan')):.1f}"
        )
        if "K_tail" in r:
            line += f" Ktail={r['K_tail']}"
            if r.get("tail_sat", 0):
                line += f" sat={r['tail_sat']}"
        import math
        if "sigma_x_rhat" in r and math.isfinite(r["sigma_x_rhat"]):
            line += (f" rhat(sx)={r['sigma_x_rhat']:.3f}"
                     f" ess(sx)={r['sigma_x_ess']:.0f}")
        print(line, flush=True)

    gs, ss = drv.run(on_eval=show)

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as fh:
        # early eval records carry NaN diagnostics (not enough draws);
        # bare NaN is not valid JSON — emit null instead
        json.dump(_json_safe(drv.history), fh, indent=1)
    print(f"history -> {args.out}")
    if drv.bank_builder is not None and len(drv.bank_builder):
        # already persisted by the driver's final-iteration checkpoint
        print(f"sample bank ({len(drv.bank_builder)} samples) -> "
              f"{drv.bank_path}")


def _json_safe(obj):
    import math

    if isinstance(obj, float) and not math.isfinite(obj):
        return None
    if isinstance(obj, dict):
        return {k: _json_safe(v) for k, v in obj.items()}
    if isinstance(obj, list):
        return [_json_safe(v) for v in obj]
    return obj


if __name__ == "__main__":
    main()
