"""IBP hybrid-MCMC launcher — the paper's experiment, end to end.

Usage:
  python -m repro.launch.mcmc --N 1000 --P 5 --iters 1000 --L 5
"""
from __future__ import annotations

import argparse
import json
import os

from repro.core.ibp import IBPHypers
from repro.data import cambridge_data, train_eval_split
from repro.runtime import DriverConfig, MCMCDriver


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--N", type=int, default=1000)
    ap.add_argument("--P", type=int, default=5)
    ap.add_argument("--iters", type=int, default=1000)
    ap.add_argument("--L", type=int, default=5)
    ap.add_argument("--K-max", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--sigma-n", type=float, default=0.5)
    ap.add_argument("--ckpt-dir", default="artifacts/ckpt/mcmc")
    ap.add_argument("--backend", default="jnp", choices=["jnp", "pallas"])
    ap.add_argument("--out", default="artifacts/mcmc_history.json")
    args = ap.parse_args(argv)

    X, Ztrue, Atrue = cambridge_data(N=args.N, sigma_n=args.sigma_n,
                                     seed=args.seed)
    X_train, X_eval = train_eval_split(X, eval_frac=0.1, seed=args.seed)

    cfg = DriverConfig(
        P=args.P, K_max=args.K_max, L=args.L, n_iters=args.iters,
        ckpt_dir=args.ckpt_dir, seed=args.seed, backend=args.backend,
    )
    drv = MCMCDriver(X_train, cfg, IBPHypers(), X_eval=X_eval)
    gs, ss = drv.run(on_eval=lambda r: print(
        f"it={r['it']:5d} t={r['t']:7.1f}s K+={r['K']:2d} "
        f"alpha={r['alpha']:.2f} sx={r['sigma_x']:.3f} "
        f"ll_eval={r.get('joint_ll_eval', float('nan')):.1f}", flush=True))

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as fh:
        json.dump(drv.history, fh, indent=1)
    print(f"history -> {args.out}")


if __name__ == "__main__":
    main()
