"""Linear-Gaussian IBP math: marginal likelihoods, conjugate posteriors, rank updates.

Model (paper Eq. 1):
    X = Z A + eps,   eps ~ N(0, sigma_x^2 I),   A_k ~ N(0, sigma_a^2 I)

All feature-indexed buffers are padded to a static ``K_max``; an ``active``
mask (float {0,1}) selects live columns.  Inactive rows/cols are arranged so
that padded linear algebra (Cholesky of W) is exact: the padded W gets unit
diagonal / zero off-diagonal in inactive slots, contributing 0 to logdet and
nothing to the trace term.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array

LOG2PI = float(jnp.log(2.0 * jnp.pi))


def mask_outer(active: Array) -> Array:
    """(K,K) mask with 1 where both row & col active."""
    return active[:, None] * active[None, :]


def padded_W(ZtZ: Array, active: Array, ratio: Array) -> Array:
    """W = ZtZ + ratio*I on active block; identity on inactive block.

    ratio = sigma_x^2 / sigma_a^2.
    """
    K = ZtZ.shape[0]
    m2 = mask_outer(active)
    W = ZtZ * m2 + ratio * jnp.eye(K) * active[:, None] * active[None, :]
    # inactive diagonal -> 1 so chol / logdet are well defined and contribute 0
    W = W + jnp.eye(K) * (1.0 - active)
    return W


def chol_inv_logdet(W: Array) -> tuple[Array, Array]:
    """Return (W^{-1}, logdet W) via Cholesky. W must be SPD."""
    L = jnp.linalg.cholesky(W)
    logdet = 2.0 * jnp.sum(jnp.log(jnp.diagonal(L)))
    eye = jnp.eye(W.shape[0], dtype=W.dtype)
    Linv = jax.scipy.linalg.solve_triangular(L, eye, lower=True)
    Winv = Linv.T @ Linv
    return Winv, logdet


def chol_inv(W: Array) -> tuple[Array, Array]:
    """Return (L, W^{-1}) via Cholesky — the exact-refactorization form the
    fast collapsed row step refreshes its carried (L, M) from."""
    L = jnp.linalg.cholesky(W)
    eye = jnp.eye(W.shape[0], dtype=W.dtype)
    Linv = jax.scipy.linalg.solve_triangular(L, eye, lower=True)
    return L, Linv.T @ Linv


def collapsed_loglik(
    trXtX: Array,
    ZtX: Array,
    ZtZ: Array,
    active: Array,
    N: Array,
    D: int,
    sigma_x: Array,
    sigma_a: Array,
) -> Array:
    """log P(X | Z) with A integrated out (paper Sec. 2 / G&G 2011 Eq. 26).

    log P = -(N D / 2) log(2 pi) - (N - K) D log sigma_x - K D log sigma_a
            - (D/2) log|W| - (1 / 2 sigma_x^2) ( tr(X^T X) - tr(X^T Z M Z^T X) )
    with W = Z^T Z + (sigma_x^2/sigma_a^2) I,  M = W^{-1}.

    All feature inputs are K_max padded + masked by ``active``.
    """
    ratio = (sigma_x / sigma_a) ** 2
    K = jnp.sum(active)
    W = padded_W(ZtZ, active, ratio)
    M, logdetW = chol_inv_logdet(W)
    ZtX_m = ZtX * active[:, None]
    quad = jnp.sum((M @ ZtX_m) * ZtX_m)  # tr( (ZtX)^T M (ZtX) )
    Nf = N.astype(jnp.float32) if hasattr(N, "astype") else jnp.float32(N)
    return (
        -0.5 * Nf * D * LOG2PI
        - (Nf - K) * D * jnp.log(sigma_x)
        - K * D * jnp.log(sigma_a)
        - 0.5 * D * logdetW
        - 0.5 / (sigma_x**2) * (trXtX - quad)
    )


def sm_downdate(M: Array, z: Array) -> tuple[Array, Array]:
    """Sherman-Morrison removal: M' = (W - z z^T)^{-1} given M = W^{-1}.

    Returns (M', log det(W - z z^T) - log det W) = (M', log(1 - z^T M z)).
    """
    Mz = M @ z
    denom = 1.0 - jnp.dot(z, Mz)
    return M + jnp.outer(Mz, Mz) / denom, jnp.log(denom)


def sm_update(M: Array, z: Array) -> tuple[Array, Array]:
    """Sherman-Morrison addition: M' = (W + z z^T)^{-1}; logdet delta = log(1+z^T M z)."""
    Mz = M @ z
    denom = 1.0 + jnp.dot(z, Mz)
    return M - jnp.outer(Mz, Mz) / denom, jnp.log(denom)


def _chol_rank1_t(Lt: Array, p: Array, sigma: float, eps: float) -> tuple[Array, Array]:
    """Core of the rank-one Cholesky up/downdate, transposed layout.

    Closed "semiseparable" form (Gill, Golub, Murray & Saunders Method C /
    Seeger 2004): with p = L^{-1} x,

        chol(L L^T + sigma x x^T) = L * chol(I + sigma p p^T)

    and chol(I + sigma p p^T) has entries T[j,j] = sqrt(d_j / d_{j-1}),
    T[i>j, j] = sigma p_i p_j / sqrt(d_j d_{j-1}) with d_j = 1 + sigma
    cumsum(p^2)_j — so the whole move is a cumulative sum + elementwise
    work: O(K^2) in dense vectorized ops with no sequential K-loop (the
    LINPACK column-rotation form is also O(K^2) but serializes K dependent
    steps, which is what dominates wall-time on CPU/TPU at our K).

    Works on Lt = L^T (upper triangular, row-major) so every pass —
    the cumulative sum over source columns in particular — runs along
    contiguous rows: (L T)^T[j] = r_j Lt[j] + sigma-coef_j * sum_{i>j}
    p_i Lt[i], and the exclusive tail sum is (p @ Lt) - inclusive-cumsum.

    Returns (Lt', ok): ``ok`` is False when some d_j fell below ``eps``,
    i.e. the downdated matrix lost positive definiteness.

    Padding contract: a padded/inactive slot j has Lt[j, j] = 1 with zero
    off-diagonals AND p_j = 0 (callers mask the rank-one vector by the
    active mask); then the slot's row scales by exactly 1 and receives
    exactly 0 — padding-transparent, no masked variant needed.
    """
    K = Lt.shape[0]
    p2 = p * p
    d = 1.0 + sigma * jnp.cumsum(p2)
    d_prev = d - sigma * p2  # d_{j-1} with d_{-1} = 1
    ok = jnp.all(d > eps) & jnp.all(d_prev > eps)
    d = jnp.maximum(d, eps)
    d_prev = jnp.maximum(d_prev, eps)
    r = jnp.sqrt(d / d_prev)               # diagonal of chol(I + sigma p p^T)
    qc = sigma * p / jnp.sqrt(d * d_prev)  # tail coefficient per column
    Gt = Lt * p[:, None]
    # Ct[j] = sum_{i > j} p_i Lt[i] — exclusive tail sums over rows. The
    # prefix sums go through a GEMM against a constant lower-triangular
    # ones matrix rather than jnp.cumsum: on CPU/TPU the K^3 matmul beats
    # the K^2 scan-lowered cumsum by ~2x at our K (BLAS/MXU vs serial scan)
    tril = jnp.tril(jnp.ones((K, K), Lt.dtype))
    acc = tril @ Gt
    Ct = acc[-1][None, :] - acc
    return Lt * r[:, None] + Ct * qc[:, None], ok


def chol_rank1_update_t(Lt: Array, p: Array) -> Array:
    """Transposed-layout rank-one update with precomputed p = L^{-1} x.

    The hot-path form: the fast collapsed row step already carries
    M = W^{-1}, so p = L^T (M x) is a matvec — no triangular solve. The
    update direction cannot lose positive definiteness: no canary.
    """
    Lp, _ = _chol_rank1_t(Lt, p, 1.0, 1e-12)
    return Lp


def chol_rank1_downdate_t(Lt: Array, p: Array, eps: float = 1e-12) -> tuple[Array, Array]:
    """Transposed-layout rank-one downdate with precomputed p = L^{-1} x.

    Returns (Lt', ok); ``ok`` False = positive definiteness lost (see
    ``chol_rank1_downdate``).
    """
    return _chol_rank1_t(Lt, p, -1.0, eps)


def chol_rank1_update(L: Array, x: Array) -> Array:
    """Rank-one Cholesky update: chol(L L^T + x x^T) in O(K^2) vector ops.

    Standalone (lower-triangular) form: does its own triangular solve for
    p. See ``_chol_rank1_t`` for the algebra + padding contract.
    """
    p = jax.scipy.linalg.solve_triangular(L, x, lower=True)
    return chol_rank1_update_t(L.T, p).T


def chol_rank1_downdate(L: Array, x: Array, eps: float = 1e-12) -> tuple[Array, Array]:
    """Rank-one Cholesky downdate: chol(L L^T - x x^T), with a canary.

    Returns (L', ok). ``ok`` is False when some partial d_j = 1 -
    cumsum(p^2)_j fell below ``eps`` — i.e. the implied matrix lost
    positive definiteness. Mathematically this never happens for our
    W - z z^T (removing a row keeps W ⪰ (sigma_x/sigma_a)^2 I), so a False
    here is a float-drift detector: the caller must refresh from the exact
    sufficient statistics. See ``_chol_rank1_t`` for algebra + padding.
    """
    p = jax.scipy.linalg.solve_triangular(L, x, lower=True)
    Lt, ok = chol_rank1_downdate_t(L.T, p, eps)
    return Lt.T, ok


def g_rank1(G: Array, H: Array, a: Array, b: Array) -> Array:
    """Move G = H Hᵀ through the rank-one map move H' = H + a bᵀ.

    G' = (H + a bᵀ)(H + a bᵀ)ᵀ = G + a(Hb)ᵀ + (Hb)aᵀ + (b·b) a aᵀ —
    a symmetric rank-two correction costing O(K² + KD), vs the O(K²D)
    G recompute it replaces in the packed collapsed flip (DESIGN.md §14).
    ``H`` is the PRE-move map (the same H the Sherman–Morrison move read).

    Evaluated as a cᵀ + c aᵀ with c = Hb + (b·b)/2 · a, so the result is
    EXACTLY symmetric whenever G is (a_i c_j + c_i a_j is commutative in
    float) — the packed flip reads G rows as columns.

    Padding contract: a padded/inactive slot j has H[j] = 0 and a_j = 0
    (callers mask the rank-one vector), so row/col j of every correction
    term is exactly 0 — padding-transparent, like the chol moves.
    """
    c = H @ b + (0.5 * jnp.dot(b, b)) * a
    return G + (jnp.outer(a, c) + jnp.outer(c, a))


# --------------------------------------------------------------------------
# occupancy-adaptive packing: K_live bucket policy + block permutations
# (DESIGN.md §14)
# --------------------------------------------------------------------------


def live_buckets(K_max: int, base: int = 8) -> tuple[int, ...]:
    """Power-of-two K_live block sizes (8, 16, 32, ...) capped by K_max.

    K_max itself is always the last bucket, so a full-occupancy chain
    degenerates to today's unpacked layout; the bucket count is
    O(log K_max), which bounds the jit compile cache of the packed scan.
    """
    if K_max < 1:
        raise ValueError(f"K_max={K_max} must be >= 1")
    bs = []
    b = base
    while b < K_max:
        bs.append(b)
        b *= 2
    bs.append(K_max)
    return tuple(bs)


def pick_bucket(buckets: tuple[int, ...], k_plus: int, headroom: int) -> int:
    """Smallest bucket with room for ``k_plus`` live features + headroom.

    Host-side policy: ``headroom`` in-block free slots guarantee the next
    per-row birth (j_new <= J_MAX) fits without a repack; when nothing
    fits, the largest bucket (== K_max) is returned — at full width the
    packed scan can never overflow.
    """
    for b in buckets:
        if b >= k_plus + headroom:
            return b
    return buckets[-1]


def block_select(active: Array, B: int) -> tuple[Array, Array]:
    """Canonical columns of the packed K_live block, ascending.

    The block is every live column plus the LOWEST-index free slots
    filling up to ``B`` — so in-canonical-order iteration over the block
    visits live columns in the oracle's order, and new-dish placement
    into the block's free slots matches the oracle's first-free-slot rule
    as long as the birth stays below ``min_out`` (the smallest
    out-of-block canonical index; every out-of-block slot is free by
    construction). Requires sum(active) <= B, which the bucket policy
    guarantees host-side.

    Returns (cols (B,) int32, min_out () int32 — K when the block covers
    everything).
    """
    K = active.shape[0]
    free_rank = jnp.cumsum(1.0 - active) * (1.0 - active)
    n_live = jnp.sum(active)
    sel = (active > 0.5) | ((free_rank >= 1.0) & (free_rank <= B - n_live))
    cols = jnp.nonzero(sel, size=B, fill_value=K - 1)[0].astype(jnp.int32)
    min_out = jnp.min(
        jnp.where(sel, K, jnp.arange(K))
    ).astype(jnp.int32)
    return cols, min_out


def a_posterior(
    ZtZ: Array,
    ZtX: Array,
    active: Array,
    sigma_x: Array,
    sigma_a: Array,
) -> tuple[Array, Array]:
    """Posterior of A | Z, X: mean = M Z^T X, per-column covariance sigma_x^2 M.

    Returns (mean (K,D) masked, M (K,K) masked+identity-padded).
    """
    ratio = (sigma_x / sigma_a) ** 2
    W = padded_W(ZtZ, active, ratio)
    M, _ = chol_inv_logdet(W)
    M = M * mask_outer(active)  # zero inactive cross terms for the draw
    mean = (M @ (ZtX * active[:, None])) * active[:, None]
    return mean, M


def a_posterior_draw(
    key: Array,
    ZtZ: Array,
    ZtX: Array,
    active: Array,
    sigma_x: Array,
    sigma_a: Array,
) -> Array:
    """Draw A ~ P(A | Z, X). Columns of A are iid N(mean_d, sigma_x^2 M)."""
    mean, M = a_posterior(ZtZ, ZtX, active, sigma_x, sigma_a)
    K = ZtZ.shape[0]
    D = ZtX.shape[1]
    # chol of sigma_x^2 M with identity padding on inactive block
    Mp = M + jnp.eye(K) * (1.0 - active)
    L = jnp.linalg.cholesky(Mp)
    eps = jax.random.normal(key, (K, D), dtype=ZtX.dtype)
    draw = mean + sigma_x * ((L @ eps) * active[:, None])
    return draw


def uncollapsed_loglik(X: Array, Z: Array, A: Array, sigma_x: Array) -> Array:
    """log N(X | Z A, sigma_x^2 I), summed over all entries."""
    R = X - Z @ A
    n = X.size
    return -0.5 * n * LOG2PI - n * jnp.log(sigma_x) - 0.5 * jnp.sum(R * R) / sigma_x**2


def z_prior_loglik(Z: Array, pi: Array, active: Array) -> Array:
    """sum_k sum_n log Bernoulli(Z_nk | pi_k) over active features."""
    p = jnp.clip(pi, 1e-6, 1.0 - 1e-6)
    ll = Z * jnp.log(p)[None, :] + (1.0 - Z) * jnp.log1p(-p)[None, :]
    return jnp.sum(ll * active[None, :])


def harmonic(N: int) -> float:
    return float(sum(1.0 / i for i in range(1, N + 1)))


def inverse_gamma_draw(key: Array, shape_param: Array, rate_param: Array) -> Array:
    """X ~ InvGamma(a, b) via 1 / Gamma(a, rate=b) (jax gamma is shape-only, scale 1)."""
    g = jax.random.gamma(key, shape_param) / rate_param
    return 1.0 / g


def gamma_draw(key: Array, shape_param: Array, rate_param: Array) -> Array:
    return jax.random.gamma(key, shape_param) / rate_param
