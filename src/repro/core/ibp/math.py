"""Linear-Gaussian IBP math: marginal likelihoods, conjugate posteriors, rank updates.

Model (paper Eq. 1):
    X = Z A + eps,   eps ~ N(0, sigma_x^2 I),   A_k ~ N(0, sigma_a^2 I)

All feature-indexed buffers are padded to a static ``K_max``; an ``active``
mask (float {0,1}) selects live columns.  Inactive rows/cols are arranged so
that padded linear algebra (Cholesky of W) is exact: the padded W gets unit
diagonal / zero off-diagonal in inactive slots, contributing 0 to logdet and
nothing to the trace term.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array

LOG2PI = float(jnp.log(2.0 * jnp.pi))


def mask_outer(active: Array) -> Array:
    """(K,K) mask with 1 where both row & col active."""
    return active[:, None] * active[None, :]


def padded_W(ZtZ: Array, active: Array, ratio: Array) -> Array:
    """W = ZtZ + ratio*I on active block; identity on inactive block.

    ratio = sigma_x^2 / sigma_a^2.
    """
    K = ZtZ.shape[0]
    m2 = mask_outer(active)
    W = ZtZ * m2 + ratio * jnp.eye(K) * active[:, None] * active[None, :]
    # inactive diagonal -> 1 so chol / logdet are well defined and contribute 0
    W = W + jnp.eye(K) * (1.0 - active)
    return W


def chol_inv_logdet(W: Array) -> tuple[Array, Array]:
    """Return (W^{-1}, logdet W) via Cholesky. W must be SPD."""
    L = jnp.linalg.cholesky(W)
    logdet = 2.0 * jnp.sum(jnp.log(jnp.diagonal(L)))
    eye = jnp.eye(W.shape[0], dtype=W.dtype)
    Linv = jax.scipy.linalg.solve_triangular(L, eye, lower=True)
    Winv = Linv.T @ Linv
    return Winv, logdet


def collapsed_loglik(
    trXtX: Array,
    ZtX: Array,
    ZtZ: Array,
    active: Array,
    N: Array,
    D: int,
    sigma_x: Array,
    sigma_a: Array,
) -> Array:
    """log P(X | Z) with A integrated out (paper Sec. 2 / G&G 2011 Eq. 26).

    log P = -(N D / 2) log(2 pi) - (N - K) D log sigma_x - K D log sigma_a
            - (D/2) log|W| - (1 / 2 sigma_x^2) ( tr(X^T X) - tr(X^T Z M Z^T X) )
    with W = Z^T Z + (sigma_x^2/sigma_a^2) I,  M = W^{-1}.

    All feature inputs are K_max padded + masked by ``active``.
    """
    ratio = (sigma_x / sigma_a) ** 2
    K = jnp.sum(active)
    W = padded_W(ZtZ, active, ratio)
    M, logdetW = chol_inv_logdet(W)
    ZtX_m = ZtX * active[:, None]
    quad = jnp.sum((M @ ZtX_m) * ZtX_m)  # tr( (ZtX)^T M (ZtX) )
    Nf = N.astype(jnp.float32) if hasattr(N, "astype") else jnp.float32(N)
    return (
        -0.5 * Nf * D * LOG2PI
        - (Nf - K) * D * jnp.log(sigma_x)
        - K * D * jnp.log(sigma_a)
        - 0.5 * D * logdetW
        - 0.5 / (sigma_x**2) * (trXtX - quad)
    )


def sm_downdate(M: Array, z: Array) -> tuple[Array, Array]:
    """Sherman-Morrison removal: M' = (W - z z^T)^{-1} given M = W^{-1}.

    Returns (M', log det(W - z z^T) - log det W) = (M', log(1 - z^T M z)).
    """
    Mz = M @ z
    denom = 1.0 - jnp.dot(z, Mz)
    return M + jnp.outer(Mz, Mz) / denom, jnp.log(denom)


def sm_update(M: Array, z: Array) -> tuple[Array, Array]:
    """Sherman-Morrison addition: M' = (W + z z^T)^{-1}; logdet delta = log(1+z^T M z)."""
    Mz = M @ z
    denom = 1.0 + jnp.dot(z, Mz)
    return M - jnp.outer(Mz, Mz) / denom, jnp.log(denom)


def a_posterior(
    ZtZ: Array,
    ZtX: Array,
    active: Array,
    sigma_x: Array,
    sigma_a: Array,
) -> tuple[Array, Array]:
    """Posterior of A | Z, X: mean = M Z^T X, per-column covariance sigma_x^2 M.

    Returns (mean (K,D) masked, M (K,K) masked+identity-padded).
    """
    ratio = (sigma_x / sigma_a) ** 2
    W = padded_W(ZtZ, active, ratio)
    M, _ = chol_inv_logdet(W)
    M = M * mask_outer(active)  # zero inactive cross terms for the draw
    mean = (M @ (ZtX * active[:, None])) * active[:, None]
    return mean, M


def a_posterior_draw(
    key: Array,
    ZtZ: Array,
    ZtX: Array,
    active: Array,
    sigma_x: Array,
    sigma_a: Array,
) -> Array:
    """Draw A ~ P(A | Z, X). Columns of A are iid N(mean_d, sigma_x^2 M)."""
    mean, M = a_posterior(ZtZ, ZtX, active, sigma_x, sigma_a)
    K = ZtZ.shape[0]
    D = ZtX.shape[1]
    # chol of sigma_x^2 M with identity padding on inactive block
    Mp = M + jnp.eye(K) * (1.0 - active)
    L = jnp.linalg.cholesky(Mp)
    eps = jax.random.normal(key, (K, D), dtype=ZtX.dtype)
    draw = mean + sigma_x * ((L @ eps) * active[:, None])
    return draw


def uncollapsed_loglik(X: Array, Z: Array, A: Array, sigma_x: Array) -> Array:
    """log N(X | Z A, sigma_x^2 I), summed over all entries."""
    R = X - Z @ A
    n = X.size
    return -0.5 * n * LOG2PI - n * jnp.log(sigma_x) - 0.5 * jnp.sum(R * R) / sigma_x**2


def z_prior_loglik(Z: Array, pi: Array, active: Array) -> Array:
    """sum_k sum_n log Bernoulli(Z_nk | pi_k) over active features."""
    p = jnp.clip(pi, 1e-6, 1.0 - 1e-6)
    ll = Z * jnp.log(p)[None, :] + (1.0 - Z) * jnp.log1p(-p)[None, :]
    return jnp.sum(ll * active[None, :])


def harmonic(N: int) -> float:
    return float(sum(1.0 / i for i in range(1, N + 1)))


def inverse_gamma_draw(key: Array, shape_param: Array, rate_param: Array) -> Array:
    """X ~ InvGamma(a, b) via 1 / Gamma(a, rate=b) (jax gamma is shape-only, scale 1)."""
    g = jax.random.gamma(key, shape_param) / rate_param
    return 1.0 / g


def gamma_draw(key: Array, shape_param: Array, rate_param: Array) -> Array:
    return jax.random.gamma(key, shape_param) / rate_param
