"""The paper's hybrid parallel MCMC sampler for the IBP.

One global iteration (paper Sec. 3 pseudocode):

  for l = 1..L sub-iterations:
      every shard p:   uncollapsed Gibbs sweep of Z over the K+ instantiated
                       features given (pi, A)                  [data-parallel]
      shard p' only:   collapsed Gibbs on its local tail features (A* integrated
                       out, residual R = X_p - Z A as data, global-N priors)
                       + MH birth of K_new ~ Poisson(alpha/N) per row
  master sync:
      psum tail mask -> promote p''s tail columns into free K+ slots
      psum (m, ZtZ, ZtX) -> deactivate dead columns, draw A | Z,X then
      pi_k ~ Beta(m_k, 1 + N - m_k)
      psum ||X - Z A||^2 -> sigma_x^2, then sigma_a^2, alpha ~ conjugates
      p' ~ Uniform{0..P-1}; clear tail

Deviation from the paper (recorded in DESIGN.md §4): the master is
*replicated* — every shard all-reduces the same sufficient statistics and
draws identical posteriors from a shared PRNG key, so the paper's explicit
gather -> master-compute -> broadcast round becomes a single all-reduce.
The draws are bitwise identical across shards, hence semantically the same
algorithm with strictly less communication.

Exactness note: on p', the instantiated-feature sweep conditions on A+ only
(tail contribution not subtracted), exactly as written in the paper's
pseudocode; the tail sampler sees R = X_p - Z A+ as its data.

Parallelism is expressed as two ORTHOGONAL axes, not a driver enum
(DESIGN.md §13): ``spec.chains`` picks the chain layout (``none`` — no
chain axis; ``vmap`` — C chains vmapped over the full iteration;
``mesh`` — C chains as a real mesh axis) and ``spec.data`` picks the
data layout (``vmap`` — P shards simulated by vmap, psum == sum over
the shard axis; ``shardmap`` — shard_map over a mesh data axis, psum ==
jax.lax.psum, the production path). ``build_hybrid_fns(spec, hyp, ...)``
is the ONE construction entry point: it reads every kernel knob
(``L``, ``backend``, ``collapsed_backend``, ``chol_refresh``, ``sync``)
off the spec and returns jitted ``(step, stale)`` functions for the
requested layout — the old per-backend entry points
(``hybrid_iteration_vmap`` / ``_multichain`` / ``hybrid_stale_pass`` /
``make_hybrid_iteration_shardmap``) are subsumed by spec layouts.
Mesh construction and shard_map go through ``repro.compat`` so the same
code runs on JAX 0.4.x and on the modern AxisType/set_mesh API.

The ``stale`` function is the bounded-staleness knob (DESIGN.md §10):
sub-iterations only, no master sync (and, on a mesh, no collectives at
all) — explicitly non-exact.

Most callers want the higher-level ``build_sampler`` (core/ibp/api.py),
which wraps these functions in a uniform init/step/stale/to_canonical
protocol and owns mesh creation + data placement.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat

from . import math as ibm
from .collapsed import DEFAULT_REFRESH, collapsed_row_scan
from .sweeps import uncollapsed_sweep

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class HybridGlobal:
    """Replicated across shards."""

    A: Array         # (K_max, D)
    pi: Array        # (K_max,)
    active: Array    # (K_max,)
    alpha: Array     # ()
    sigma_x: Array   # ()
    sigma_a: Array   # ()
    key: Array       # PRNG key (shared)
    p_prime: Array   # () int32
    it: Array        # () int32
    overflow: Array  # () int32 — promoted-feature drops due to K_max capacity
    tail_sat: Array  # () int32 — tail rows whose accepted MH birth was
    #                  vetoed by K_tail capacity (drives adaptive K_tail
    #                  growth at the driver's restart boundary)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class HybridShard:
    """Sharded along the observation axis. Leading axis = shard (size P)."""

    Z: Array            # (P, N_p, K_max)
    Z_tail: Array       # (P, N_p, K_tail)
    tail_active: Array  # (P, K_tail)


def init_hybrid(
    key: Array,
    X_shards: Array,  # (P, N_p, D)
    K_max: int,
    K_tail: int = 8,
    alpha: float = 3.0,
    sigma_x: float = 1.0,
    sigma_a: float = 1.0,
    K_init: int = 4,
    init_from_data: bool = True,
) -> tuple[HybridGlobal, HybridShard]:
    P_, N_p, D = X_shards.shape
    dtype = X_shards.dtype
    K_init = min(K_init, K_max)
    k0, k1, k2 = jax.random.split(key, 3)
    Z = jnp.zeros((P_, N_p, K_max), dtype)
    if K_init > 0:
        Z = Z.at[:, :, :K_init].set(
            jax.random.bernoulli(k0, 0.5, (P_, N_p, K_init)).astype(dtype)
        )
    A = jnp.zeros((K_max, D), dtype)
    if K_init > 0:
        if init_from_data:
            # seed features with (noised) data rows spread across shards —
            # avoids the all-features-die nucleation trap at cold start
            flat = X_shards.reshape(-1, D)
            stride = max(1, flat.shape[0] // K_init)
            seeds = flat[::stride][:K_init]
            A = A.at[:K_init].set(
                seeds + 0.1 * jax.random.normal(k1, seeds.shape, dtype)
            )
        else:
            A = A.at[:K_init].set(
                jax.random.normal(k1, (K_init, D), dtype) * sigma_a
            )
    active = jnp.zeros((K_max,), dtype).at[:K_init].set(1.0)
    gs = HybridGlobal(
        A=A,
        pi=jnp.zeros((K_max,), dtype).at[:K_init].set(0.5),
        active=active,
        alpha=jnp.asarray(alpha, dtype),
        sigma_x=jnp.asarray(sigma_x, dtype),
        sigma_a=jnp.asarray(sigma_a, dtype),
        key=k2,
        p_prime=jnp.asarray(0, jnp.int32),
        it=jnp.asarray(0, jnp.int32),
        overflow=jnp.asarray(0, jnp.int32),
        tail_sat=jnp.asarray(0, jnp.int32),
    )
    ss = HybridShard(
        Z=Z,
        Z_tail=jnp.zeros((P_, N_p, K_tail), dtype),
        tail_active=jnp.zeros((P_, K_tail), dtype),
    )
    return gs, ss


# --------------------------------------------------------------------------
# per-shard kernels (unbatched: no leading P axis)
# --------------------------------------------------------------------------


def _tail_sub_iteration(
    X_p: Array,
    Z: Array,
    Z_tail: Array,
    tail_active: Array,
    gs: HybridGlobal,
    N_global: float,
    key: Array,
    collapsed_backend: str = "ref",
    chol_refresh: int = DEFAULT_REFRESH,
    k_live_pack: bool = False,
) -> tuple[Array, Array, Array]:
    """Collapsed Gibbs + MH births on the tail (runs on p' only).

    ``collapsed_backend`` selects the row-step implementation (DESIGN.md
    §12): the K_tail ≤ 8 problem is too small for the O(K²) carry to
    matter, but the "pallas" flavor moves the K-sequential bit-flip
    recurrence into the ``collapsed_row`` kernel, keeping the whole tail
    recurrence VMEM-resident on TPU. ``k_live_pack`` (the spec's
    ``k_live_buckets`` knob) selects the unified core's carried-G float
    path — in-jit the block is the full K_tail width either way, so what
    the tail gains from ``pack=True`` is the carried G = HHᵀ (DESIGN.md
    §12).

    Returns (Z_tail, tail_active, n_sat): ``n_sat`` counts rows whose
    accepted MH birth was vetoed purely by K_tail capacity — the tail-
    saturation signal driving adaptive K_tail growth.
    """
    # residual given instantiated features = the tail model's data
    R = X_p - (Z * gs.active[None, :]) @ gs.A
    m_t = jnp.sum(Z_tail, axis=0)
    ZtZ_t = Z_tail.T @ Z_tail
    ZtR = Z_tail.T @ R
    # u_chunk_rows=n_rows: this entry is vmapped (chains/shards) — the
    # chunked refill would lower to select and regenerate per row
    Z_tail, tail_active, _, _, m_t, _, n_sat = collapsed_row_scan(
        Z_tail, tail_active, ZtZ_t, ZtR, m_t, R, key,
        gs.alpha, gs.sigma_x, gs.sigma_a,
        N=N_global, birth="mh", backend=collapsed_backend,
        refresh_every=chol_refresh, pack=k_live_pack,
        u_chunk_rows=R.shape[0],
    )
    # prune dead tail columns
    tail_active = tail_active * (m_t > 0.5)
    Z_tail = Z_tail * tail_active[None, :]
    return Z_tail, tail_active, n_sat


def shard_sub_iterations(
    X_p: Array,
    Z: Array,
    Z_tail: Array,
    tail_active: Array,
    gs: HybridGlobal,
    shard_idx: Array,
    N_global: float,
    L: int,
    backend: str = "jnp",
    collapsed_backend: str = "ref",
    chol_refresh: int = DEFAULT_REFRESH,
    k_live_pack: bool = False,
) -> tuple[Array, Array, Array, Array]:
    """L sub-iterations of the paper's inner loop on one shard.

    Returns (Z, Z_tail, tail_active, n_sat) — ``n_sat`` is the tail-
    saturation count summed over this shard's tail sub-iterations
    (nonzero only on p').
    """
    key_shard = jax.random.fold_in(gs.key, shard_idx)
    is_pprime = shard_idx == gs.p_prime

    def one(l, carry):
        Z, Z_tail, tail_active, n_sat = carry
        kl = jax.random.fold_in(key_shard, l)
        ku, kt = jax.random.split(kl)
        Z = uncollapsed_sweep(
            X_p, Z, gs.A, gs.pi, gs.active, gs.sigma_x, ku, backend=backend
        )

        def with_tail(args):
            Z_tail, tail_active, n_sat = args
            Z_tail, tail_active, sat = _tail_sub_iteration(
                X_p, Z, Z_tail, tail_active, gs, N_global, kt,
                collapsed_backend=collapsed_backend,
                chol_refresh=chol_refresh,
                k_live_pack=k_live_pack,
            )
            return Z_tail, tail_active, n_sat + sat

        Z_tail, tail_active, n_sat = jax.lax.cond(
            is_pprime, with_tail, lambda a: a, (Z_tail, tail_active, n_sat)
        )
        return Z, Z_tail, tail_active, n_sat

    Z, Z_tail, tail_active, n_sat = jax.lax.fori_loop(
        0, L, one, (Z, Z_tail, tail_active, jnp.zeros((), jnp.int32))
    )
    return Z, Z_tail, tail_active, n_sat


def promote_tail(
    Z: Array,
    Z_tail: Array,
    tail_active_g: Array,
    active: Array,
) -> tuple[Array, Array, Array]:
    """Scatter tail columns into free K+ slots (identical on every shard).

    ``tail_active_g`` is the globally-reduced tail mask (only p' contributes),
    so every shard computes the same slot assignment. Shards other than p'
    scatter zero columns. Returns (Z_new, active_new, n_dropped).
    """
    K_max = Z.shape[1]
    free = 1.0 - active
    n_free = jnp.sum(free)
    rank = jnp.cumsum(tail_active_g) * tail_active_g        # 1-indexed among tails
    kept = tail_active_g * (rank <= n_free)
    n_drop = jnp.sum(tail_active_g) - jnp.sum(kept)
    # target slot of tail j = index of the rank_j-th free slot
    # searchsorted over cumsum(free) gives that index
    cums = jnp.cumsum(free)
    tgt = jnp.searchsorted(cums, jnp.maximum(rank, 1.0))    # (K_tail,)
    tgt = jnp.clip(tgt, 0, K_max - 1).astype(jnp.int32)
    cols = Z_tail * kept[None, :]
    Z_new = Z.at[:, tgt].add(cols)                          # zero cols are no-ops
    active_new = active.at[tgt].max(kept)
    return Z_new, active_new, n_drop.astype(jnp.int32)


def local_stats(X_p: Array, Z: Array) -> dict[str, Array]:
    return {
        "m": jnp.sum(Z, axis=0),
        "ZtZ": Z.T @ Z,
        "ZtX": Z.T @ X_p,
    }


def local_sse(X_p: Array, Z: Array, A: Array, active: Array) -> Array:
    R = X_p - (Z * active[None, :]) @ A
    return jnp.sum(R * R)


def master_step1(
    stats: dict[str, Array],
    active: Array,
    gs: HybridGlobal,
    N_global: float,
    D: int,
) -> tuple[Array, Array, Array, Array]:
    """Deaths, A | Z,X draw, pi | Z draw — identical on every shard."""
    key = gs.key
    k_a, k_pi = jax.random.split(jax.random.fold_in(key, 101))
    m = stats["m"] * active
    active = active * (m > 0.5)
    mask2 = ibm.mask_outer(active)
    ZtZ = stats["ZtZ"] * mask2
    ZtX = stats["ZtX"] * active[:, None]
    A = ibm.a_posterior_draw(k_a, ZtZ, ZtX, active, gs.sigma_x, gs.sigma_a)
    # pi_k | Z ~ Beta(m_k, 1 + N - m_k) for instantiated features
    a_beta = jnp.maximum(m, 1e-6)
    b_beta = 1.0 + N_global - m
    pi = jax.random.beta(k_pi, a_beta, b_beta) * active
    return A, pi, active, m


def master_step2(
    sse: Array,
    A: Array,
    active: Array,
    gs: HybridGlobal,
    hyp,
    N_global: float,
    D: int,
    P_: int,
) -> tuple[Array, Array, Array, Array]:
    """sigma_x, sigma_a, alpha, p' — identical on every shard."""
    k_sx, k_sa, k_al, k_pp = jax.random.split(jax.random.fold_in(gs.key, 202), 4)
    k_plus = jnp.sum(active)
    if hyp.resample_sigmas:
        sx2 = ibm.inverse_gamma_draw(
            k_sx, hyp.a_sx + 0.5 * N_global * D, hyp.b_sx + 0.5 * sse
        )
        sigma_x = jnp.sqrt(sx2)
        a_ss = jnp.sum(A * A * active[:, None])
        sa2 = ibm.inverse_gamma_draw(
            k_sa, hyp.a_sa + 0.5 * k_plus * D, hyp.b_sa + 0.5 * a_ss
        )
        # with no live features the draw is pure heavy-tailed prior and can
        # wander into a region where births are impossible — hold it instead
        sigma_a = jnp.where(k_plus > 0, jnp.sqrt(sa2), gs.sigma_a)
    else:
        sigma_x, sigma_a = gs.sigma_x, gs.sigma_a
    if hyp.resample_alpha:
        HN = ibm.harmonic(int(N_global))
        alpha = ibm.gamma_draw(k_al, hyp.a_alpha + k_plus, hyp.b_alpha + HN)
    else:
        alpha = gs.alpha
    p_prime = jax.random.randint(k_pp, (), 0, P_)
    return sigma_x, sigma_a, alpha, p_prime


# --------------------------------------------------------------------------
# driver 1: vmap-simulated shards (single device; benchmarks/tests)
# --------------------------------------------------------------------------


def _hybrid_iteration_body(
    X_shards: Array,            # (P, N_p, D)
    gs: HybridGlobal,
    ss: HybridShard,
    hyp,
    L: int,
    N_g: float,
    backend: str,
    collapsed_backend: str = "ref",
    chol_refresh: int = DEFAULT_REFRESH,
    k_live_pack: bool = False,
) -> tuple[HybridGlobal, HybridShard]:
    """One full hybrid iteration for ONE chain (vmap-simulated shards).

    Kept free of jit/static plumbing so every layout can reuse it:
    ``_build_vmap_fns`` jits it directly or vmaps it over a chain axis,
    and the chains-mesh x data-vmap layout runs it per chain device
    (``build_hybrid_fns``).
    """
    P_, N_p, D = X_shards.shape

    sub = partial(
        shard_sub_iterations, N_global=N_g, L=L, backend=backend,
        collapsed_backend=collapsed_backend, chol_refresh=chol_refresh,
        k_live_pack=k_live_pack,
    )
    Z, Z_tail, tail_active, n_sat = jax.vmap(
        sub, in_axes=(0, 0, 0, 0, None, 0)
    )(X_shards, ss.Z, ss.Z_tail, ss.tail_active, gs, jnp.arange(P_))
    n_sat = jnp.sum(n_sat)  # only p' contributes

    # ---- master sync (simulated psum = sum over shard axis)
    tail_g = jnp.sum(tail_active, axis=0)  # only p' is nonzero
    Z, active_new, n_drop = jax.vmap(
        promote_tail, in_axes=(0, 0, None, None)
    )(Z, Z_tail, tail_g, gs.active)
    active_new = active_new[0]  # identical across shards
    n_drop = n_drop[0]

    stats = jax.vmap(local_stats)(X_shards, Z)
    stats = jax.tree.map(lambda x: jnp.sum(x, axis=0), stats)
    A, pi, active, m = master_step1(stats, active_new, gs, N_g, D)
    Z = Z * active[None, None, :]

    sse = jnp.sum(jax.vmap(local_sse, in_axes=(0, 0, None, None))(
        X_shards, Z, A, active
    ))
    sigma_x, sigma_a, alpha, p_prime = master_step2(
        sse, A, active, gs, hyp, N_g, D, P_
    )

    gs_new = HybridGlobal(
        A=A, pi=pi, active=active, alpha=alpha,
        sigma_x=sigma_x, sigma_a=sigma_a,
        key=jax.random.fold_in(gs.key, 7),
        p_prime=p_prime, it=gs.it + 1,
        overflow=gs.overflow + n_drop,
        tail_sat=gs.tail_sat + n_sat,
    )
    ss_new = HybridShard(
        Z=Z,
        Z_tail=jnp.zeros_like(ss.Z_tail),
        tail_active=jnp.zeros_like(ss.tail_active),
    )
    return gs_new, ss_new


# --------------------------------------------------------------------------
# multi-chain init: chain axis over every state leaf
# --------------------------------------------------------------------------


def init_multichain(
    key: Array,
    X_shards: Array,  # (P, N_p, D) — shared by every chain
    C: int,
    K_max: int,
    **kw,
) -> tuple[HybridGlobal, HybridShard]:
    """C independent chains: every state leaf gains a leading chain axis.

    Chains share the data but start from split PRNG keys, so their
    initial Z draws, feature seeds, and whole trajectories are
    independent — exactly what split-R-hat needs.
    """
    keys = jax.random.split(key, C)
    return jax.vmap(lambda k: init_hybrid(k, X_shards, K_max, **kw))(keys)


def _hybrid_stale_body(
    X_shards: Array,
    gs: HybridGlobal,
    ss: HybridShard,
    L: int,
    N_g: float,
    backend: str,
    collapsed_backend: str,
    chol_refresh: int,
    k_live_pack: bool = False,
) -> tuple[HybridGlobal, HybridShard]:
    """Bounded-staleness pass for ONE chain: shard sub-iterations WITHOUT
    the master sync (DESIGN.md §10).

    Shards keep Gibbs-sweeping Z (and p' keeps exploring its tail) against
    stale global parameters; tails carry over into the next full
    iteration's promotion. Non-exact by construction.

    The key consumed by the sweeps (fold 13) and the key handed to the
    next pass (fold 14) MUST differ — returning the consumed key would
    make the next iteration's sub-iterations replay the exact same
    per-(shard, l) uniform stream.
    """
    P_ = X_shards.shape[0]
    gs_sweep = dataclasses.replace(gs, key=jax.random.fold_in(gs.key, 13))
    sub = partial(shard_sub_iterations, N_global=N_g, L=L, backend=backend,
                  collapsed_backend=collapsed_backend,
                  chol_refresh=chol_refresh, k_live_pack=k_live_pack)
    Z, Z_tail, tail_active, _ = jax.vmap(
        sub, in_axes=(0, 0, 0, 0, None, 0)
    )(X_shards, ss.Z, ss.Z_tail, ss.tail_active, gs_sweep, jnp.arange(P_))
    # stale passes don't touch gs — saturation on them is uncounted (the
    # pass is explicitly non-exact; the counter stays a sync-boundary
    # quantity)
    gs_out = dataclasses.replace(gs, key=jax.random.fold_in(gs.key, 14))
    return gs_out, HybridShard(Z=Z, Z_tail=Z_tail, tail_active=tail_active)


# --------------------------------------------------------------------------
# THE spec-driven construction path (DESIGN.md §13)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class HybridFns:
    """Jitted iteration functions in a layout's NATIVE calling convention.

    * data="vmap" layouts (chains "none"/"vmap"):
        ``step(X_shards, gs, ss) -> (gs, ss)`` with HybridShard state
        (chain-batched leaves when chains="vmap").
    * mesh layouts (data="shardmap" and/or chains="mesh"):
        ``step(X_native, gs, Z, Z_tail, tail_active) -> (gs, Z, Zt, ta)``
        with device-resident mesh-layout buffers.

    ``stale`` is the bounded-staleness pass in the same convention.
    """

    step: Any
    stale: Any


def build_hybrid_fns(
    spec,
    hyp,
    *,
    N_global: int,
    mesh=None,
    data_axes: tuple[str, ...] = ("data",),
    chain_axes: tuple[str, ...] = ("chains",),
) -> HybridFns:
    """Build the hybrid iteration for ``spec``'s parallelism layout.

    This is hybrid.py's ONE construction entry point: every kernel knob
    (``L``, ``backend``, ``collapsed_backend``, ``chol_refresh``,
    ``sync``) and the parallelism layout (``chains`` x ``data``) are read
    off ``spec`` (a ``repro.core.ibp.api.SamplerSpec`` or anything with
    those attributes). ``mesh`` is required for shard_map layouts;
    ``data_axes`` may name several mesh axes (flattened into the P
    processors — the production dry-run path), ``chain_axes`` exactly one.

    The same per-shard kernels back every layout, so the statistical
    algorithm is identical everywhere; only psum's realization changes
    (sum over a vmap axis vs. jax.lax.psum over mesh axes).
    """
    N_g = float(N_global)
    if spec.chains in ("none", "vmap") and spec.data == "vmap":
        return _build_vmap_fns(spec, hyp, N_g)
    if mesh is None:
        raise ValueError(
            f"layout chains={spec.chains!r} x data={spec.data!r} needs a "
            f"mesh; pass mesh= (build_sampler constructs one from the spec)"
        )
    return _build_mesh_fns(spec, hyp, N_g, mesh, data_axes, chain_axes)


def _build_vmap_fns(spec, hyp, N_g: float) -> HybridFns:
    """Single-device layouts: P shards simulated by vmap, optional chain
    axis vmapped OVER the full iteration (DESIGN.md §11)."""
    L, be = spec.L, spec.backend
    cb, cr = spec.collapsed_backend, spec.chol_refresh
    pk = spec.k_live_buckets == "on"

    def step_one(Xs, gs, ss):
        return _hybrid_iteration_body(Xs, gs, ss, hyp, L, N_g, be, cb, cr,
                                      pk)

    def stale_one(Xs, gs, ss):
        return _hybrid_stale_body(Xs, gs, ss, L, N_g, be, cb, cr, pk)

    if spec.chains == "vmap":
        # built ONCE as jit(vmap(...)) — a bare vmap-of-jit would re-trace
        # the full iteration body on every call
        step = jax.vmap(step_one, in_axes=(None, 0, 0))
        stale = jax.vmap(stale_one, in_axes=(None, 0, 0))
    else:
        step, stale = step_one, stale_one
    return HybridFns(step=jax.jit(step), stale=jax.jit(stale))


def _build_mesh_fns(spec, hyp, N_g: float, mesh,
                    data_axes: tuple[str, ...],
                    chain_axes: tuple[str, ...]) -> HybridFns:
    """shard_map layouts: data sharded over ``data_axes``
    (spec.data="shardmap") and/or chains sharded over ``chain_axes``
    (spec.chains="mesh"); composing both gives the 2-D chains x data mesh.

    ``spec.sync`` selects the master-sync schedule (DESIGN.md §8):

    * ``"staged"`` — three sequential all-reduces (tail mask -> promote ->
      (m, ZtZ, ZtX) -> draw A -> sse), a direct transliteration of the
      paper's "send summary statistics to the master" with the broadcast
      folded away by the replicated-master trick.
    * ``"fused"`` — ONE all-reduce. Exactness-preserving rewrites: (i) each
      shard computes its local stats with its OWN tail pre-scattered (zero
      columns everywhere except p', so the reduced stats equal the staged
      post-promotion stats); (ii) the residual SSE comes from the identity
      ||X - Z A||^2 = tr(X^T X) - 2<A, Z^T X> + <A, (Z^T Z) A>, evaluated
      from the already-reduced stats — no second reduction; (iii) the tail
      mask and tr(X^T X) ride in the same flattened payload. At the paper's
      statistics sizes (K <= 64) the sync is latency-bound, so collective
      COUNT, not bytes, is the cost — 3x fewer round trips.

    The stale pass runs with NO collectives at all — the whole point of
    bounded staleness on a real mesh is skipping the sync, so it never
    leaves the mesh layout or touches psum. Bitwise-equivalent to the
    vmap stale pass (same fold-13 sweep key, same fold-14 key advance).

    Chains are independent by construction: each chain block carries its
    own replicated master (gs leaves sharded over the chain axis), and no
    collective ever crosses ``chain_axes`` — the composed layout is C
    independent copies of the data-parallel algorithm.
    """
    import numpy as np

    L, be = spec.L, spec.backend
    cb, cr = spec.collapsed_backend, spec.chol_refresh
    pk = spec.k_live_buckets == "on"
    sync = spec.sync
    chainful = spec.chains == "mesh"
    data_sharded = spec.data == "shardmap"
    if sync not in ("staged", "fused"):
        raise ValueError(f"sync={sync!r} not in ('staged', 'fused')")
    if chainful and len(chain_axes) != 1:
        raise ValueError(f"chains='mesh' needs exactly one chain axis, "
                         f"got {chain_axes}")
    P_ = (int(np.prod([mesh.shape[a] for a in data_axes]))
          if data_sharded else spec.P)
    d_ent = data_axes if len(data_axes) > 1 else data_axes[0]

    def make_fn(stale: bool):
        def call(X, gs: HybridGlobal, Z, Z_tail, tail_active):
            D = X.shape[-1]

            def finish(gs, A, pi, active, sse, n_drop, n_sat, Zt_p, ta_p):
                sigma_x, sigma_a, alpha, p_prime = master_step2(
                    sse, A, active, gs, hyp, N_g, D, P_
                )
                gs_new = HybridGlobal(
                    A=A, pi=pi, active=active, alpha=alpha,
                    sigma_x=sigma_x, sigma_a=sigma_a,
                    key=jax.random.fold_in(gs.key, 7),
                    p_prime=p_prime, it=gs.it + 1,
                    overflow=gs.overflow + n_drop,
                    tail_sat=gs.tail_sat + n_sat,
                )
                return gs_new, jnp.zeros_like(Zt_p), jnp.zeros_like(ta_p)

            def block_stale(X_p, gs, Z_p, Zt_p, ta_p):
                ta = ta_p[0]
                idx = compat.axis_index(data_axes)
                gs_sweep = dataclasses.replace(
                    gs, key=jax.random.fold_in(gs.key, 13)
                )
                Z_p, Zt_p, ta, _ = shard_sub_iterations(
                    X_p, Z_p, Zt_p, ta, gs_sweep, idx, N_g, L, be, cb, cr,
                    pk,
                )
                gs_out = dataclasses.replace(
                    gs, key=jax.random.fold_in(gs.key, 14)
                )
                return gs_out, Z_p, Zt_p, ta[None, :]

            def block_staged(X_p, gs, Z_p, Zt_p, ta_p):
                ta = ta_p[0]  # (1, K_tail) local block -> (K_tail,)
                idx = compat.axis_index(data_axes)
                Z_p, Zt_p2, ta, n_sat = shard_sub_iterations(
                    X_p, Z_p, Zt_p, ta, gs, idx, N_g, L, be, cb, cr, pk
                )
                tail_g, n_sat_g = jax.lax.psum((ta, n_sat), data_axes)  # AR 1
                Z_p, active_new, n_drop = promote_tail(Z_p, Zt_p2, tail_g,
                                                       gs.active)
                stats = local_stats(X_p, Z_p)
                stats = jax.lax.psum(stats, data_axes)              # AR 2
                A, pi, active, m = master_step1(stats, active_new, gs,
                                                N_g, D)
                Z_p = Z_p * active[None, :]
                sse = jax.lax.psum(                                  # AR 3
                    local_sse(X_p, Z_p, A, active), data_axes)
                gs_new, Zt0, ta0 = finish(gs, A, pi, active, sse, n_drop,
                                          n_sat_g, Zt_p, ta_p)
                return gs_new, Z_p, Zt0, ta0

            def block_fused(X_p, gs, Z_p, Zt_p, ta_p):
                ta = ta_p[0]
                idx = compat.axis_index(data_axes)
                Z_p, Zt_p2, ta, n_sat = shard_sub_iterations(
                    X_p, Z_p, Zt_p, ta, gs, idx, N_g, L, be, cb, cr, pk
                )
                K_max = Z_p.shape[1]
                K_tail = ta.shape[0]
                # local stats WITH own tail pre-scattered (non-p' adds
                # zeros; p' uses the same deterministic slot assignment
                # every shard re-derives after the reduce)
                Z_stats, _, _ = promote_tail(Z_p, Zt_p2, ta, gs.active)
                stats = local_stats(X_p, Z_stats)
                # the saturation count rides the single payload as a
                # float scalar (small exact integers — f32-exact)
                payload = jnp.concatenate([
                    stats["ZtZ"].reshape(-1),
                    stats["ZtX"].reshape(-1),
                    stats["m"],
                    ta,
                    jnp.sum(X_p * X_p)[None],
                    n_sat.astype(X_p.dtype)[None],
                ])
                g = jax.lax.psum(payload, data_axes)                # AR (only)
                o1 = K_max * K_max
                o2 = o1 + K_max * X_p.shape[1]
                ZtZ = g[:o1].reshape(K_max, K_max)
                ZtX = g[o1:o2].reshape(K_max, X_p.shape[1])
                m_g = g[o2:o2 + K_max]
                tail_g = g[o2 + K_max:o2 + K_max + K_tail]
                xx = g[-2]
                n_sat_g = g[-1].astype(jnp.int32)
                Z_p, active_new, n_drop = promote_tail(Z_p, Zt_p2, tail_g,
                                                       gs.active)
                A, pi, active, m = master_step1(
                    {"m": m_g, "ZtZ": ZtZ, "ZtX": ZtX}, active_new, gs,
                    N_g, D
                )
                Z_p = Z_p * active[None, :]
                # SSE identity — exact, no second reduction
                ZtXm = ZtX * active[:, None]
                ZtZm = ZtZ * ibm.mask_outer(active)
                sse = xx - 2.0 * jnp.sum(A * ZtXm) + jnp.sum(A * (ZtZm @ A))
                gs_new, Zt0, ta0 = finish(gs, A, pi, active, sse, n_drop,
                                          n_sat_g, Zt_p, ta_p)
                return gs_new, Z_p, Zt0, ta0

            def block_vmap_data(X_full, gs, Z_c, Zt_c, ta_c):
                # data axis simulated by vmap INSIDE this chain's device:
                # one full single-chain iteration, no collectives
                ss_c = HybridShard(Z=Z_c, Z_tail=Zt_c, tail_active=ta_c)
                if stale:
                    gs2, ss2 = _hybrid_stale_body(X_full, gs, ss_c, L, N_g,
                                                  be, cb, cr, pk)
                else:
                    gs2, ss2 = _hybrid_iteration_body(X_full, gs, ss_c, hyp,
                                                      L, N_g, be, cb, cr,
                                                      pk)
                return gs2, ss2.Z, ss2.Z_tail, ss2.tail_active

            if data_sharded:
                block = block_stale if stale else (
                    block_fused if sync == "fused" else block_staged)
            else:
                block = block_vmap_data

            if chainful:
                def shard_fn(X_b, gs_b, Z_b, Zt_b, ta_b):
                    # strip this chain's length-1 block axis, run the
                    # single-chain block, put the axis back
                    gs_c = jax.tree.map(lambda x: x[0], gs_b)
                    gs2, Z2, Zt2, ta2 = block(X_b, gs_c, Z_b[0], Zt_b[0],
                                              ta_b[0])
                    return (jax.tree.map(lambda x: x[None], gs2),
                            Z2[None], Zt2[None], ta2[None])
            else:
                shard_fn = block

            c_ent = chain_axes[0]
            if chainful and data_sharded:
                x_spec = P(d_ent)                 # replicated over chains
                g_leaf, z_spec = P(c_ent), P(c_ent, d_ent)
            elif chainful:
                x_spec = P()                      # full (P, N_p, D) copy
                g_leaf = z_spec = P(c_ent)
            else:
                x_spec, g_leaf, z_spec = P(d_ent), P(), P(d_ent)
            gspec = jax.tree.map(lambda _: g_leaf, gs)
            return compat.shard_map(
                shard_fn,
                mesh=mesh,
                in_specs=(x_spec, gspec, z_spec, z_spec, z_spec),
                out_specs=(gspec, z_spec, z_spec, z_spec),
                check_vma=False,
            )(X, gs, Z, Z_tail, tail_active)

        return jax.jit(call)

    return HybridFns(step=make_fn(stale=False), stale=make_fn(stale=True))
