"""IBP sampler state pytrees and initialization."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class IBPHypers:
    """Fixed hyper-hyper parameters (priors). Static pytree leaves (floats)."""

    a_alpha: float = 1.0   # Gamma prior on alpha (shape)
    b_alpha: float = 1.0   # Gamma prior on alpha (rate)
    a_sx: float = 1.0      # InvGamma prior on sigma_x^2
    b_sx: float = 1.0
    a_sa: float = 1.0      # InvGamma prior on sigma_a^2
    b_sa: float = 1.0
    resample_sigmas: bool = dataclasses.field(default=True, metadata={"static": True})
    resample_alpha: bool = dataclasses.field(default=True, metadata={"static": True})


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class IBPState:
    """Global (replicated) + sharded state of the hybrid sampler.

    Z is sharded along axis 0 (observations); all feature-indexed buffers are
    padded to K_max. ``active`` marks instantiated (K+) features; ``tail``
    marks shard-local uninstantiated features being explored by processor p'.
    """

    Z: Array            # (N[_p], K_max) float {0,1}
    A: Array            # (K_max, D)
    pi: Array           # (K_max,)
    active: Array       # (K_max,) float {0,1}   instantiated features K+
    tail: Array         # (K_max,) float {0,1}   p'-local tail features K*
    alpha: Array        # ()
    sigma_x: Array      # ()
    sigma_a: Array      # ()
    key: Array          # PRNG key (shared; shards fold in their index)
    p_prime: Array      # () int32 — which shard owns the collapsed tail
    it: Array           # () int32 — iteration counter

    @property
    def k_plus(self) -> Array:
        return jnp.sum(self.active).astype(jnp.int32)

    @property
    def k_max(self) -> int:
        return self.Z.shape[1]


def init_state(
    key: Array,
    N: int,
    D: int,
    K_max: int,
    alpha: float = 3.0,
    sigma_x: float = 1.0,
    sigma_a: float = 1.0,
    K_init: int = 1,
    dtype: Any = jnp.float32,
) -> IBPState:
    """Start with K_init random singleton-ish features."""
    k0, k1, k2 = jax.random.split(key, 3)
    Z = jnp.zeros((N, K_max), dtype)
    Z = Z.at[:, :K_init].set(
        jax.random.bernoulli(k0, 0.5, (N, K_init)).astype(dtype)
    )
    A = jnp.zeros((K_max, D), dtype)
    A = A.at[:K_init].set(jax.random.normal(k1, (K_init, D), dtype) * sigma_a)
    active = jnp.zeros((K_max,), dtype).at[:K_init].set(1.0)
    pi = jnp.zeros((K_max,), dtype).at[:K_init].set(0.5)
    return IBPState(
        Z=Z,
        A=A,
        pi=pi,
        active=active,
        tail=jnp.zeros((K_max,), dtype),
        alpha=jnp.asarray(alpha, dtype),
        sigma_x=jnp.asarray(sigma_x, dtype),
        sigma_a=jnp.asarray(sigma_a, dtype),
        key=k2,
        p_prime=jnp.asarray(0, jnp.int32),
        it=jnp.asarray(0, jnp.int32),
    )
