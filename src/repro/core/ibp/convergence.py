"""Convergence diagnostics for (multi-chain) MCMC output (DESIGN.md §11).

These are host-side diagnostics over collected sample traces — plain
numpy on ``(C, T)`` arrays (C chains, T post-burn-in draws). They back
three consumers:

* ``runtime/driver.py`` eval records (split-R-hat / ESS / MCSE of the
  monitored scalars, computed from the driver's per-iteration trace);
* the statistical test suite (``tests/test_exactness.py``), which
  replaces hard single-chain tolerances with MCSE/ESS-aware z-tests;
* the Geweke-style "getting it right" joint-distribution check, where
  two successive-conditional simulators are compared via ``mean_diff_z``.

Conventions follow Vehtari et al. (2021) rank-free forms: split-R-hat
splits every chain in half (so a single stuck-then-jumped chain is
caught even at C=1), and ESS uses Geyer's initial-positive-sequence
truncation over chain-averaged autocovariances.
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "split_rhat",
    "ess",
    "mcse",
    "geweke_z",
    "mean_diff_z",
    "summarize",
]


def _as_chains(x) -> np.ndarray:
    """Coerce to (C, T) float64; a 1-D trace becomes one chain."""
    a = np.asarray(x, np.float64)
    if a.ndim == 1:
        a = a[None, :]
    if a.ndim != 2:
        raise ValueError(f"expected (C, T) or (T,) trace, got shape {a.shape}")
    return a


def _split_halves(a: np.ndarray) -> np.ndarray:
    """(C, T) -> (2C, T//2): each chain split into first/second half."""
    C, T = a.shape
    h = T // 2
    return np.concatenate([a[:, :h], a[:, T - h:]], axis=0)


def split_rhat(x) -> float:
    """Potential scale reduction over half-split chains.

    ~1 at convergence; conventional alarm threshold 1.01-1.05. Returns
    NaN when there are fewer than 4 draws per half-chain or zero
    variance everywhere (a constant trace is 'converged' but R-hat is
    undefined; callers treat NaN as no-evidence-of-trouble).
    """
    a = _split_halves(_as_chains(x))
    M, T = a.shape
    if T < 4:
        return float("nan")
    means = a.mean(axis=1)
    W = a.var(axis=1, ddof=1).mean()
    B = T * means.var(ddof=1)
    if W <= 0.0:
        return float("nan") if B <= 0.0 else float("inf")
    var_plus = (T - 1) / T * W + B / T
    return float(np.sqrt(var_plus / W))


def ess(x) -> float:
    """Effective sample size across chains (Geyer initial positive seq.).

    Autocovariances are averaged across chains at each lag; the sum of
    paired autocorrelations is truncated at the first non-positive pair.
    Bounded to [1, C*T].
    """
    a = _as_chains(x)
    C, T = a.shape
    n = C * T
    if T < 4:
        return float(n)
    W = a.var(axis=1, ddof=1).mean()
    means = a.mean(axis=1)
    var_plus = (T - 1) / T * W + (T * means.var(ddof=1) / T if C > 1 else 0.0)
    if var_plus <= 0.0:
        return float(n)

    # chain-averaged autocovariance via FFT
    am = a - means[:, None]
    m = 1 << (2 * T - 1).bit_length()
    f = np.fft.rfft(am, m, axis=1)
    acov = np.fft.irfft(f * np.conj(f), m, axis=1)[:, :T].real / T
    rho = 1.0 - (W - acov.mean(axis=0)) / var_plus   # (T,) combined rho_t

    # Geyer: sum rho over pairs (rho_{2k} + rho_{2k+1}) while positive
    tau = 1.0
    t = 1
    while t + 1 < T:
        pair = rho[t] + rho[t + 1]
        if pair <= 0.0:
            break
        tau += 2.0 * pair
        t += 2
    return float(np.clip(n / tau, 1.0, n))


def mcse(x) -> float:
    """Monte-Carlo standard error of the mean: sd / sqrt(ESS)."""
    a = _as_chains(x)
    sd = a.std(ddof=1)
    if sd == 0.0:
        return 0.0
    return float(sd / np.sqrt(ess(a)))


def geweke_z(x, first: float = 0.1, last: float = 0.5) -> float:
    """Geweke (1992) stationarity z-score of one pooled trace.

    Compares the mean of the first ``first`` fraction against the last
    ``last`` fraction, standardized by ESS-aware MCSEs of each window.
    |z| > ~3 signals the window means disagree (non-stationary trace).
    """
    a = _as_chains(x)
    T = a.shape[1]
    w0 = a[:, : max(2, int(first * T))]
    w1 = a[:, T - max(2, int(last * T)):]
    se = np.hypot(mcse(w0), mcse(w1))
    if se == 0.0:
        return 0.0
    return float((w0.mean() - w1.mean()) / se)


def mean_diff_z(x, y) -> float:
    """z-score of E[x] - E[y] under independent-chain MCSEs.

    The MCSE/ESS-aware replacement for hard relative tolerances when
    checking that two samplers target the same posterior: |z| < ~4
    means the observed gap is within Monte-Carlo error.
    """
    se = np.hypot(mcse(x), mcse(y))
    if se == 0.0:
        return 0.0 if np.isclose(_as_chains(x).mean(), _as_chains(y).mean()) \
            else float("inf")
    return float((_as_chains(x).mean() - _as_chains(y).mean()) / se)


def summarize(x, prefix: str = "") -> dict[str, float]:
    """{rhat, ess, mcse, mean, sd} of one (C, T) trace, for eval records."""
    a = _as_chains(x)
    p = f"{prefix}_" if prefix else ""
    return {
        f"{p}mean": float(a.mean()),
        f"{p}sd": float(a.std(ddof=1)) if a.size > 1 else 0.0,
        f"{p}rhat": split_rhat(a),
        f"{p}ess": ess(a),
        f"{p}mcse": mcse(a),
    }
