"""Fully uncollapsed Gibbs sampler (finite beta-Bernoulli approximation).

The paper's 'poor mixing' baseline: instantiate pi and A for a finite K
truncation (Eq. 2), sweep Z | pi, A, then conjugate draws for pi, A, sigmas.
Trivially parallelizable but slow to instantiate good new features — included
for completeness and for ablation benchmarks.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import math as ibm
from .state import IBPHypers, IBPState
from .sweeps import uncollapsed_sweep

Array = jax.Array


@partial(jax.jit, static_argnames=("hyp",))
def uncollapsed_step(state: IBPState, X: Array, hyp: IBPHypers) -> IBPState:
    """One iteration: Z | pi,A ; A | Z,X ; pi | Z (finite Beta(alpha/K, 1)); hypers."""
    N, D = X.shape
    K = state.Z.shape[1]
    active = jnp.ones((K,), X.dtype)  # finite model: all K columns live
    key, kz, ka, kpi, ksx, ksa, kal = jax.random.split(state.key, 7)

    Z = uncollapsed_sweep(X, state.Z, state.A, state.pi, active, state.sigma_x, kz)

    m = jnp.sum(Z, axis=0)
    ZtZ = Z.T @ Z
    ZtX = Z.T @ X
    A = ibm.a_posterior_draw(ka, ZtZ, ZtX, active, state.sigma_x, state.sigma_a)

    # finite-model posterior: pi_k ~ Beta(alpha/K + m_k, 1 + N - m_k)
    pi = jax.random.beta(kpi, state.alpha / K + m, 1.0 + N - m)

    sigma_x, sigma_a, alpha = state.sigma_x, state.sigma_a, state.alpha
    if hyp.resample_sigmas:
        sse = jnp.sum((X - Z @ A) ** 2)
        sigma_x = jnp.sqrt(
            ibm.inverse_gamma_draw(ksx, hyp.a_sx + 0.5 * N * D, hyp.b_sx + 0.5 * sse)
        )
        sigma_a = jnp.sqrt(
            ibm.inverse_gamma_draw(
                ksa, hyp.a_sa + 0.5 * K * D, hyp.b_sa + 0.5 * jnp.sum(A * A)
            )
        )
    if hyp.resample_alpha:
        # finite-model conjugate: alpha ~ Gamma(a + K_active-ish, b + H_N);
        # we use the standard IBP form with K+ = #columns with m_k > 0
        k_plus = jnp.sum(m > 0.5)
        alpha = ibm.gamma_draw(
            kal, hyp.a_alpha + k_plus, hyp.b_alpha + ibm.harmonic(N)
        )

    return IBPState(
        Z=Z, A=A, pi=pi, active=active, tail=state.tail,
        alpha=alpha, sigma_x=sigma_x, sigma_a=sigma_a, key=key,
        p_prime=state.p_prime, it=state.it + 1,
    )
