"""Gibbs sweep primitives.

``uncollapsed_sweep`` is the hot loop of the paper's hybrid sampler: for every
row n (data-parallel) and every instantiated feature k (sequential — the
likelihood couples features through the residual), resample

    P(Z_nk = 1 | pi_k, A, X_n) ∝ pi_k · N(X_n | Z_n A, sigma_x^2 I).

Implementation: keep the residual R = X - Z A as the carried state and scan
over k with rank-1 updates — O(K · N · D) per sweep, fully vectorized over
rows. This is the jnp oracle; ``repro.kernels.gibbs_flip`` is the Pallas TPU
version with the residual pinned in VMEM (select with backend="pallas").
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

Array = jax.Array


def _logit(p: Array) -> Array:
    p = jnp.clip(p, 1e-6, 1.0 - 1e-6)
    return jnp.log(p) - jnp.log1p(-p)


def uncollapsed_sweep(
    X: Array,
    Z: Array,
    A: Array,
    pi: Array,
    active: Array,
    sigma_x: Array,
    key: Array,
    backend: str = "jnp",
) -> Array:
    """One full Gibbs sweep of Z | pi, A over active columns. Returns new Z."""
    if backend == "pallas":
        from repro.kernels.gibbs_flip import ops as _gf_ops

        return _gf_ops.gibbs_flip(X, Z, A, pi, active, sigma_x, key)
    return _uncollapsed_sweep_jnp(X, Z, A, pi, active, sigma_x, key)


@partial(jax.jit, static_argnames=())
def _uncollapsed_sweep_jnp(
    X: Array,
    Z: Array,
    A: Array,
    pi: Array,
    active: Array,
    sigma_x: Array,
    key: Array,
) -> Array:
    N, K = Z.shape
    R = X - Z @ A                      # residual under current Z
    anorm2 = jnp.sum(A * A, axis=1)    # (K,)
    lpi = _logit(pi)
    # pre-drawn uniforms, in logit space so the accept test is logit > u
    u = _logit(jax.random.uniform(key, (N, K), dtype=X.dtype))
    inv2s2 = 0.5 / (sigma_x**2)

    def body(carry, k):
        R, Z = carry
        a_k = A[k]
        z_k = Z[:, k]
        # residual with Z_nk = 0
        R0 = R + z_k[:, None] * a_k[None, :]
        # loglik(z=1) - loglik(z=0) = (2 R0·a_k - |a_k|^2) / (2 sigma^2)
        dll = (2.0 * (R0 @ a_k) - anorm2[k]) * inv2s2
        logits = lpi[k] + dll
        znew = jnp.where(active[k] > 0, (logits > u[:, k]).astype(Z.dtype), z_k)
        R = R0 - znew[:, None] * a_k[None, :]
        Z = Z.at[:, k].set(znew)
        return (R, Z), None

    (R, Z), _ = jax.lax.scan(body, (R, Z), jnp.arange(K))
    return Z


def sufficient_stats(X: Array, Z: Array) -> tuple[Array, Array, Array, Array]:
    """(m, ZtZ, ZtX, trXtX) for this shard — what the master sync reduces."""
    m = jnp.sum(Z, axis=0)
    ZtZ = Z.T @ Z
    ZtX = Z.T @ X
    trXtX = jnp.sum(X * X)
    return m, ZtZ, ZtX, trXtX
