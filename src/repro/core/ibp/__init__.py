from .state import IBPHypers, IBPState, init_state
from .sweeps import sufficient_stats, uncollapsed_sweep
from .collapsed import collapsed_sweep
from .uncollapsed import uncollapsed_step
from .hybrid import (
    HybridFns,
    HybridGlobal,
    HybridShard,
    build_hybrid_fns,
    init_hybrid,
    init_multichain,
)
from .api import (
    DRIVERS,
    Sampler,
    SamplerSpec,
    build_sampler,
)
from . import convergence
from . import predict
from .predict import BankBuilder, SampleBank

__all__ = [
    "IBPHypers",
    "IBPState",
    "init_state",
    "uncollapsed_sweep",
    "sufficient_stats",
    "collapsed_sweep",
    "uncollapsed_step",
    "HybridFns",
    "HybridGlobal",
    "HybridShard",
    "build_hybrid_fns",
    "init_hybrid",
    "init_multichain",
    "DRIVERS",
    "Sampler",
    "SamplerSpec",
    "build_sampler",
    "convergence",
    "predict",
    "SampleBank",
    "BankBuilder",
]
