from .state import IBPHypers, IBPState, init_state
from .sweeps import sufficient_stats, uncollapsed_sweep
from .collapsed import collapsed_sweep
from .uncollapsed import uncollapsed_step
from .hybrid import (
    HybridGlobal,
    HybridShard,
    hybrid_iteration_multichain,
    hybrid_iteration_vmap,
    hybrid_stale_pass,
    init_hybrid,
    init_multichain,
    make_hybrid_iteration_shardmap,
    make_hybrid_stale_pass_shardmap,
)
from . import convergence

__all__ = [
    "IBPHypers",
    "IBPState",
    "init_state",
    "uncollapsed_sweep",
    "sufficient_stats",
    "collapsed_sweep",
    "uncollapsed_step",
    "HybridGlobal",
    "HybridShard",
    "init_hybrid",
    "init_multichain",
    "hybrid_iteration_vmap",
    "hybrid_iteration_multichain",
    "hybrid_stale_pass",
    "make_hybrid_iteration_shardmap",
    "make_hybrid_stale_pass_shardmap",
    "convergence",
]
