from .state import IBPHypers, IBPState, init_state
from .sweeps import sufficient_stats, uncollapsed_sweep
from .collapsed import collapsed_sweep
from .uncollapsed import uncollapsed_step
from .hybrid import (
    HybridGlobal,
    HybridShard,
    hybrid_iteration_vmap,
    init_hybrid,
    make_hybrid_iteration_shardmap,
)

__all__ = [
    "IBPHypers",
    "IBPState",
    "init_state",
    "uncollapsed_sweep",
    "sufficient_stats",
    "collapsed_sweep",
    "uncollapsed_step",
    "HybridGlobal",
    "HybridShard",
    "init_hybrid",
    "hybrid_iteration_vmap",
    "make_hybrid_iteration_shardmap",
]
