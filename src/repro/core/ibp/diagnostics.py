"""Evaluation: joint log-likelihood on held-out data (paper Fig. 1) and
posterior feature recovery (paper Fig. 2).

The joint log-likelihood metrics were deduped onto the predictive
serving subsystem (DESIGN.md §15): ``heldout_joint_loglik`` and
``train_joint_loglik`` below are re-exports of the canonical
implementations in ``repro.core.ibp.predict`` (same signatures, same
PRNG stream, residual scoring through the ``gaussian_sse`` kernel
family). For ensemble scoring over a harvested ``SampleBank`` —
encode / impute / anomaly / the logsumexp mixture estimator — use
``predict`` directly.
"""
from __future__ import annotations

import numpy as np

from .predict import heldout_joint_loglik, train_joint_loglik  # noqa: F401

__all__ = ["heldout_joint_loglik", "train_joint_loglik", "match_features"]


def match_features(A_est: np.ndarray, A_true: np.ndarray) -> tuple[np.ndarray, float]:
    """Greedy L2 matching of recovered features to ground truth.

    Returns (A_est reordered to match A_true rows, mean per-feature SSE).
    """
    A_est = np.asarray(A_est, dtype=np.float64)
    A_true = np.asarray(A_true, dtype=np.float64)
    Kt = A_true.shape[0]
    used: set[int] = set()
    picked = []
    sses = []
    for t in range(Kt):
        best, best_sse = -1, np.inf
        for e in range(A_est.shape[0]):
            if e in used:
                continue
            sse = float(np.sum((A_est[e] - A_true[t]) ** 2))
            if sse < best_sse:
                best, best_sse = e, sse
        used.add(best)
        picked.append(A_est[best] if best >= 0 else np.zeros_like(A_true[t]))
        sses.append(best_sse)
    return np.stack(picked), float(np.mean(sses))
