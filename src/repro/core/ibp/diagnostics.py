"""Evaluation: joint log-likelihood on held-out data (paper Fig. 1) and
posterior feature recovery (paper Fig. 2)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import math as ibm
from .sweeps import uncollapsed_sweep

Array = jax.Array


@partial(jax.jit, static_argnames=("n_sweeps",))
def heldout_joint_loglik(
    X_test: Array,
    A: Array,
    pi: Array,
    active: Array,
    sigma_x: Array,
    key: Array,
    n_sweeps: int = 3,
) -> Array:
    """log P(X_test, Z_test | A, pi, sigma) with Z_test imputed by short
    uncollapsed Gibbs given the posterior draw (paper's Fig. 1 metric:
    'joint log likelihood of P(X,Z) on a held-out evaluation set')."""
    N, D = X_test.shape
    K = A.shape[0]
    Z = jnp.zeros((N, K), X_test.dtype)

    def body(Z, l):
        Z = uncollapsed_sweep(
            X_test, Z, A, pi, active, sigma_x, jax.random.fold_in(key, l)
        )
        return Z, None

    Z, _ = jax.lax.scan(body, Z, jnp.arange(n_sweeps))
    ll = ibm.uncollapsed_loglik(X_test, Z * active[None, :], A, sigma_x)
    ll = ll + ibm.z_prior_loglik(Z, pi, active)
    return ll


def train_joint_loglik(
    X: Array, Z: Array, A: Array, pi: Array, active: Array, sigma_x: Array
) -> Array:
    """log P(X, Z | A, pi, sigma) on the training rows (for monitoring)."""
    ll = ibm.uncollapsed_loglik(X, Z * active[None, :], A, sigma_x)
    return ll + ibm.z_prior_loglik(Z, pi, active)


def match_features(A_est: np.ndarray, A_true: np.ndarray) -> tuple[np.ndarray, float]:
    """Greedy L2 matching of recovered features to ground truth.

    Returns (A_est reordered to match A_true rows, mean per-feature SSE).
    """
    A_est = np.asarray(A_est, dtype=np.float64)
    A_true = np.asarray(A_true, dtype=np.float64)
    Kt = A_true.shape[0]
    used: set[int] = set()
    picked = []
    sses = []
    for t in range(Kt):
        best, best_sse = -1, np.inf
        for e in range(A_est.shape[0]):
            if e in used:
                continue
            sse = float(np.sum((A_est[e] - A_true[t]) ** 2))
            if sse < best_sse:
                best, best_sse = e, sse
        used.add(best)
        picked.append(A_est[best] if best >= 0 else np.zeros_like(A_true[t]))
        sses.append(best_sse)
    return np.stack(picked), float(np.mean(sses))
