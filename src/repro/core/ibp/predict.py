"""Posterior-predictive serving subsystem: SampleBank + batched scoring.

The paper's whole evaluation is posterior-predictive (held-out joint
log-likelihood, Fig. 1), and the ROADMAP north star is *serving* a
posterior, not just producing a trace. This module is the layer that
turns a finished (or in-flight) MCMC run into a usable predictive
model (DESIGN.md §15):

* ``SampleBank`` — a compact, chain-aware ensemble of S post-burn-in
  posterior samples (A, pi, active, sigma_x, sigma_a, alpha, chain, it),
  live-K packed to the §14 bucket ladder (the bank's feature width is
  the smallest power-of-two bucket holding the largest live set across
  its samples, NOT the sampler's K_max). The per-sample Cholesky factor
  chol(Ā Āᵀ + sigma_x² I) used by the encode initializer is computed
  ONCE at harvest time and cached in the bank — neither scoring nor
  bank rebuilds refactorize. Persisted through ``checkpoint.save_arrays`` (npz,
  self-describing) and restorable with no sampler state at all.
* ``encode`` — Rao-Blackwellized posterior feature probabilities
  p(z*_k = 1 | x*, sample) for NEW rows, via per-sample Gibbs passes
  over z* (conditional probabilities averaged over post-burn sweeps);
  ``exact_posterior`` is the 2^K enumeration oracle for small K.
* ``impute`` — E[x_miss | x_obs] under the ensemble by masked-Gaussian
  conditioning: only observed dimensions enter the Gibbs likelihood,
  and E[x_miss | x_obs, s] = E[z | x_obs, s] @ A_s by linearity.
* ``predictive_loglik`` / ``anomaly_score`` — the logsumexp-over-samples
  mixture estimator  log p̂(x*) = logsumexp_s ll_s(x*) − log S  with
  ll_s the per-sample joint log-likelihood (z* imputed by the same
  Gibbs pass — the paper's Fig. 1 "joint log P(X, Z)" metric,
  row-decomposed). ``heldout_joint_loglik`` / ``train_joint_loglik``
  are the ONE canonical implementation of that per-sample metric
  (``diagnostics`` re-exports them; the numpy ``joint_loglik_np`` loop
  survives only as the test oracle).

Every scoring op is jit-compiled and batched over (S samples × B rows):
one dispatch scores the whole ensemble against the whole microbatch.
``predictive_loglik_naive`` keeps the un-batched per-sample loop as the
benchmark baseline (benchmarks/predict.py), and
``make_sharded_scorer`` dispatches a scorer over a mesh "data" axis so
a bank scores row-sharded batches with the same chains×data mesh
machinery the sampler uses.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_arrays, save_arrays

from . import math as ibm
from .sweeps import uncollapsed_sweep

Array = jax.Array

BANK_FORMAT = 1           # bumped on layout changes; load() checks it
DEFAULT_ENCODE_SWEEPS = 8
DEFAULT_LL_SWEEPS = 3     # matches the historical heldout_joint_loglik
ENUM_MAX_K = 16           # 2^K patterns — the exact oracle's hard cap


# --------------------------------------------------------------------------
# the bank
# --------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SampleBank:
    """S posterior samples, feature axis packed to the bucket ladder.

    All leaves are arrays with a leading S axis, so the bank is a pytree
    that jitted scorers close over / take as an argument directly.
    ``chol_f`` is the cached per-sample lower Cholesky factor of
    F_s = Ā_s Ā_sᵀ + sigma_x,s² I (Ā = A masked by ``active``) — the
    ridge map the encode initializer solves against; caching it at
    harvest time is what keeps scoring free of per-call refactorizations.
    """

    A: Array        # (S, K, D)   feature weights (posterior draws)
    pi: Array       # (S, K)      feature probabilities
    active: Array   # (S, K)      live-feature mask (float {0,1})
    sigma_x: Array  # (S,)
    sigma_a: Array  # (S,)
    alpha: Array    # (S,)
    chain: Array    # (S,) int32  which chain the sample came from
    it: Array       # (S,) int32  harvest iteration
    chol_f: Array   # (S, K, K)   cached chol(Ā Āᵀ + sigma_x² I), lower

    @property
    def S(self) -> int:
        return self.A.shape[0]

    @property
    def K(self) -> int:
        return self.A.shape[1]

    @property
    def D(self) -> int:
        return self.A.shape[2]

    # ---- persistence (self-describing npz; no sampler state involved) ----
    def save(self, path: str) -> str:
        arrs = {f.name: np.asarray(getattr(self, f.name))
                for f in dataclasses.fields(self)}
        arrs["_format"] = np.asarray(BANK_FORMAT, np.int32)
        return save_arrays(path, arrs)

    @classmethod
    def load(cls, path: str) -> "SampleBank":
        arrs = load_arrays(path)
        fmt = int(arrs.pop("_format", 0))
        if fmt != BANK_FORMAT:
            raise ValueError(
                f"sample bank {path} has format {fmt}, expected "
                f"{BANK_FORMAT} — re-harvest with this version"
            )
        names = {f.name for f in dataclasses.fields(cls)}
        missing = names - set(arrs)
        if missing:
            raise ValueError(f"sample bank {path} is missing {sorted(missing)}")
        return cls(**{k: jnp.asarray(v) for k, v in arrs.items()
                      if k in names})


class BankBuilder:
    """Host-side harvest accumulator: compacts each sample's live
    features (canonical order preserved) and packs the bank to the §14
    bucket ladder at build time.

    The driver calls ``add_state`` at harvest cadence (chain-aware: a
    chain-batched state contributes one sample per chain), then
    ``build()`` — which pads every sample to the bank bucket (smallest
    power-of-two bucket ≥ the largest live set). Each sample's encode
    factor chol(Ā Āᵀ + σ_x² I) is computed ONCE at ``add`` time on the
    live block only: the full-width matrix is block-diagonal (dead rows
    of Ā are zero), so padding the factor is an exact embedding —
    live-block chol in the corner, σ_x on the dead diagonal. ``build``
    therefore does no linear algebra and no jit, so the driver can
    rebuild the bank at every checkpoint cadence for free.
    """

    def __init__(self, K_max: int):
        self.K_max = int(K_max)
        self._rows: list[dict] = []

    def __len__(self) -> int:
        return len(self._rows)

    @property
    def max_live(self) -> int:
        return max((r["A"].shape[0] for r in self._rows), default=0)

    def add(self, A, pi, active, sigma_x, sigma_a, alpha,
            chain: int = 0, it: int = 0, chol=None) -> None:
        """One posterior sample in canonical (K_max-padded) layout.

        ``chol`` is the live-block encode factor when the caller already
        has it (``extend_from`` — a restart must not refactorize);
        freshly harvested samples compute it here, once."""
        act = np.asarray(active, np.float32)
        live = np.flatnonzero(act > 0.5)
        sx = float(sigma_x)
        if chol is None:
            Al = np.asarray(A, np.float32)[live].astype(np.float64)
            chol = np.linalg.cholesky(Al @ Al.T + sx**2 * np.eye(len(live)))
        self._rows.append({
            "A": np.asarray(A, np.float32)[live],
            "pi": np.asarray(pi, np.float32)[live],
            "chol": np.asarray(chol, np.float32),
            "sigma_x": sx, "sigma_a": float(sigma_a),
            "alpha": float(alpha), "chain": int(chain), "it": int(it),
        })

    def add_state(self, gs, it: int = 0) -> int:
        """Harvest from a HybridGlobal (chainless or chain-batched).

        Returns the number of samples added (== n_chains)."""
        A = np.asarray(gs.A)
        if A.ndim == 3:  # chain-batched
            pi, act = np.asarray(gs.pi), np.asarray(gs.active)
            sx, sa = np.asarray(gs.sigma_x), np.asarray(gs.sigma_a)
            al = np.asarray(gs.alpha)
            for c in range(A.shape[0]):
                self.add(A[c], pi[c], act[c], sx[c], sa[c], al[c],
                         chain=c, it=it)
            return A.shape[0]
        self.add(A, gs.pi, gs.active, gs.sigma_x, gs.sigma_a, gs.alpha,
                 chain=0, it=it)
        return 1

    def extend_from(self, bank: SampleBank) -> int:
        """Re-seed the builder from a persisted bank (driver restarts:
        harvesting continues across crash/growth restarts instead of
        overwriting the bank with a shorter ensemble). The cached encode
        factors come along — a built bank keeps live features in the
        leading slots, so each factor's live block is its top-left
        corner and nothing is refactorized."""
        chol = np.asarray(bank.chol_f)
        for s in range(bank.S):
            k = int(np.sum(np.asarray(bank.active[s]) > 0.5))
            self.add(bank.A[s], bank.pi[s], bank.active[s],
                     bank.sigma_x[s], bank.sigma_a[s], bank.alpha[s],
                     chain=int(bank.chain[s]), it=int(bank.it[s]),
                     chol=chol[s, :k, :k])
        return bank.S

    def prune_after(self, it: int) -> int:
        """Drop samples harvested AFTER iteration ``it``. Restart
        reconciliation: a restore rewinds the chain to its checkpoint
        step and re-runs the iterations since, which re-harvests the
        same draws — pruning to the restored step first keeps every
        sample exactly once. Returns the number dropped."""
        n0 = len(self._rows)
        self._rows = [r for r in self._rows if r["it"] <= it]
        return n0 - len(self._rows)

    def build(self) -> SampleBank:
        if not self._rows:
            raise ValueError("empty bank: no samples harvested (is "
                             "harvest_every set and past harvest_burn?)")
        buckets = ibm.live_buckets(self.K_max)
        B = ibm.pick_bucket(buckets, self.max_live, 0)
        S = len(self._rows)
        D = self._rows[0]["A"].shape[1]  # (0, D) even with no live features
        A = np.zeros((S, B, D), np.float32)
        pi = np.zeros((S, B), np.float32)
        act = np.zeros((S, B), np.float32)
        chol = np.zeros((S, B, B), np.float32)
        for s, r in enumerate(self._rows):
            k = r["A"].shape[0]
            A[s, :k] = r["A"]
            pi[s, :k] = r["pi"]
            act[s, :k] = 1.0
            # exact block-diagonal embedding of the add-time factor
            chol[s, :k, :k] = r["chol"]
            chol[s, range(k, B), range(k, B)] = r["sigma_x"]
        bank = SampleBank(
            A=jnp.asarray(A), pi=jnp.asarray(pi), active=jnp.asarray(act),
            sigma_x=jnp.asarray([r["sigma_x"] for r in self._rows],
                                dtype=np.float32),
            sigma_a=jnp.asarray([r["sigma_a"] for r in self._rows],
                                dtype=np.float32),
            alpha=jnp.asarray([r["alpha"] for r in self._rows],
                              dtype=np.float32),
            chain=jnp.asarray([r["chain"] for r in self._rows],
                              dtype=np.int32),
            it=jnp.asarray([r["it"] for r in self._rows], dtype=np.int32),
            chol_f=jnp.asarray(chol),
        )
        return bank


# --------------------------------------------------------------------------
# per-sample core: masked Rao-Blackwellized Gibbs over z*
# --------------------------------------------------------------------------


def _logit(p: Array) -> Array:
    p = jnp.clip(p, 1e-6, 1.0 - 1e-6)
    return jnp.log(p) - jnp.log1p(-p)


def _gibbs_encode_one(A, pi, active, sigma_x, chol_f, X, mask, key,
                      n_sweeps: int, rb_from: int, masked: bool = True):
    """Masked Gibbs over z for B rows under ONE posterior sample.

    Returns (probs (B, K), Z (B, K)): ``probs`` is the Rao-Blackwellized
    marginal estimate — the conditional p(z_k = 1 | z_-k, x_obs)
    evaluated at each bit's resample, averaged over sweeps
    ``rb_from .. n_sweeps-1`` — and ``Z`` the final Gibbs draw.

    Only observed dimensions (mask = 1) enter the likelihood: the
    carried residual is masked, and the per-bit |a_k|² is the masked
    row-wise norm — exactly conditioning the Gaussian on x_obs.
    The chain starts from the cached ridge map (bank ``chol_f``): z0 =
    1[F⁻¹ Ā x_obs > 1/2], a deterministic warm start that costs one
    cached triangular solve, never a factorization.

    Hot-path shape discipline (what makes the (S × B) batching ≥ 5x the
    per-sample loop, benchmarks/predict.py): the masked per-bit norms
    ‖a_k‖²_obs collapse to ONE up-front (B, K) GEMM (mask is 0/1, so
    masked-square = mask @ (A∘A)ᵀ), and the per-bit likelihood delta is
    a GEMV against the carried masked residual — with the identity
    R0·a_obs = Rm·a_k + z_k ‖a_k‖²_obs there is no (B, D) temporary on
    the bit step beyond the single fused residual update. Under the
    vmap over S these GEMVs batch into one einsum per bit.
    """
    B, D = X.shape
    K = A.shape[0]
    Am = A * active[:, None]
    Xm = X * mask if masked else X
    # ridge warm start from the cached factor
    y = jax.scipy.linalg.cho_solve((chol_f, True), Am @ Xm.T).T  # (B, K)
    Z = (y > 0.5).astype(X.dtype) * active[None, :]
    Rm = Xm - (Z @ Am) * mask if masked else Xm - Z @ Am
    # fully-observed rows share one ‖a_k‖² per feature — ``masked`` is a
    # TRACE-TIME branch, so the unmasked hot path (serving loglik /
    # anomaly on complete rows) never materializes per-row norms nor
    # pays the two extra (B, D) mask passes per bit step
    anorm2_t = ((A * A) @ mask.T if masked
                else jnp.sum(A * A, axis=1)[:, None])  # (K, B) | (K, 1)
    lpi = _logit(pi)
    inv2s2 = 0.5 / (sigma_x**2)
    uu = jax.random.uniform(key, (n_sweeps, K, B), dtype=X.dtype)
    u = _logit(jnp.clip(uu, 1e-7, 1.0 - 1e-7))

    # Everything the bit step reads rides the scan's xs (no dynamic
    # gathers), and Z is REBUILT from the scan's stacked outputs instead
    # of per-bit column scatters: a bit step touches other bits only
    # through the carried residual, and its own column was last written
    # one full sweep ago — so the sweep-entry Z.T is a valid xs.
    def sweep(carry, u_s):
        Rm, Zt = carry  # Zt: (K, B), sweep-entry transpose

        def bit(Rm, xs):
            a_k, an, lpi_k, act_k, u_k, z_k = xs
            # R0·(a_k ∘ mask) = Rm·a_k + z_k ‖a_k‖²_obs  (Rm is masked)
            dll = (2.0 * (Rm @ a_k + z_k * an) - an) * inv2s2
            logits = lpi_k + dll
            znew = jnp.where(act_k > 0, (logits > u_k).astype(Rm.dtype),
                             z_k)
            prob = jax.nn.sigmoid(logits) * act_k
            upd = (znew - z_k)[:, None] * a_k[None, :]
            Rm = Rm - (upd * mask if masked else upd)
            return Rm, (znew, prob)

        Rm, (Zt, probs) = jax.lax.scan(
            bit, Rm, (A, anorm2_t, lpi, active, u_s, Zt))
        return (Rm, Zt), probs  # (K, B)

    (Rm, Zt), probs_all = jax.lax.scan(sweep, (Rm, Z.T), u)
    denom = max(n_sweeps - rb_from, 1)
    w = (jnp.arange(n_sweeps) >= rb_from).astype(X.dtype) / denom
    probs = jnp.einsum("s,skb->bk", w, probs_all)
    return probs, Zt.T


def _rows_joint_loglik(A, pi, active, sigma_x, X, Z, mask):
    """Per-row joint log p(x_obs, z | sample), (B,). Pure jnp — the
    (S, B)-batched building block of every mixture estimator here."""
    Am = A * active[:, None]
    R = (X - Z @ Am) * mask
    n_obs = jnp.sum(mask, axis=-1)
    ll = (-0.5 * n_obs * ibm.LOG2PI - n_obs * jnp.log(sigma_x)
          - 0.5 * jnp.sum(R * R, axis=-1) / sigma_x**2)
    p = jnp.clip(pi, 1e-6, 1.0 - 1e-6)
    lz = Z * jnp.log(p)[None, :] + (1.0 - Z) * jnp.log1p(-p)[None, :]
    return ll + jnp.sum(lz * active[None, :], axis=-1)


def _score_one(A, pi, active, sigma_x, chol_f, X, mask, key,
               n_sweeps: int, rb_from: int, masked: bool = True):
    """(probs, Z, rows_ll) for one sample — the vmapped-over-S core."""
    probs, Z = _gibbs_encode_one(A, pi, active, sigma_x, chol_f, X, mask,
                                 key, n_sweeps, rb_from, masked)
    ll = _rows_joint_loglik(A, pi, active, sigma_x, X, Z, mask)
    return probs, Z, ll


@partial(jax.jit, static_argnames=("n_sweeps", "rb_from", "masked"))
def _score_bank(bank: SampleBank, X: Array, mask: Array, key: Array,
                n_sweeps: int, rb_from: int, masked: bool = True):
    """THE batched scorer: one jitted dispatch over (S samples × B rows).

    Returns (probs (S, B, K), Z (S, B, K), rows_ll (S, B))."""
    keys = jax.random.split(key, bank.A.shape[0])
    one = partial(_score_one, n_sweeps=n_sweeps, rb_from=rb_from,
                  masked=masked)
    return jax.vmap(
        one, in_axes=(0, 0, 0, 0, 0, None, None, 0)
    )(bank.A, bank.pi, bank.active, bank.sigma_x, bank.chol_f,
      X, mask, keys)


def _as_mask(X: Array, mask) -> Array:
    return jnp.ones_like(X) if mask is None else jnp.asarray(mask, X.dtype)


# --------------------------------------------------------------------------
# public predictive ops
# --------------------------------------------------------------------------


def encode(bank: SampleBank, X, key, *, mask=None,
           n_sweeps: int = DEFAULT_ENCODE_SWEEPS,
           return_draws: bool = False):
    """Rao-Blackwellized p(z*_k = 1 | x*, sample) for new rows.

    Returns (S, B, K) posterior feature probabilities (one slice per
    bank sample); with ``return_draws`` also the final Gibbs draws
    (S, B, K). ``mask`` (B, D) marks observed dimensions (None = all)."""
    X = jnp.asarray(X)
    probs, Z, _ = _score_bank(bank, X, _as_mask(X, mask), key,
                              n_sweeps, n_sweeps // 2,
                              masked=mask is not None)
    return (probs, Z) if return_draws else probs


def impute(bank: SampleBank, X, mask, key, *,
           n_sweeps: int = DEFAULT_ENCODE_SWEEPS):
    """E[x | x_obs] under the ensemble; observed entries pass through.

    Masked-Gaussian conditioning: the Gibbs pass conditions z on the
    observed dimensions only, and by linearity E[x_miss | x_obs, s] =
    E[z | x_obs, s] @ A_s — the RB probabilities are exactly that
    conditional mean estimate. Ensemble = mean over samples."""
    X = jnp.asarray(X)
    m = _as_mask(X, mask)
    probs, _, _ = _score_bank(bank, X, m, key, n_sweeps, n_sweeps // 2,
                              masked=mask is not None)
    recon = jnp.mean(
        jnp.einsum("sbk,skd->sbd", probs,
                   bank.A * bank.active[:, :, None]), axis=0)
    return m * X + (1.0 - m) * recon


def predictive_loglik(bank: SampleBank, X, key, *, mask=None,
                      n_sweeps: int = DEFAULT_LL_SWEEPS,
                      per_sample: bool = False):
    """Mixture estimator log p̂(x*_b) = logsumexp_s ll_sb − log S, (B,).

    ll_sb is the per-sample joint log-likelihood with z* imputed by the
    per-sample Gibbs pass (the paper's Fig. 1 metric, row-decomposed) —
    the canonical replacement for the old per-sample-only
    ``heldout_joint_loglik``. ``per_sample`` additionally returns the
    (S, B) per-sample rows for diagnostics."""
    X = jnp.asarray(X)
    _, _, lls = _score_bank(bank, X, _as_mask(X, mask), key,
                            n_sweeps, n_sweeps // 2,
                            masked=mask is not None)
    mix = jax.scipy.special.logsumexp(lls, axis=0) - jnp.log(lls.shape[0])
    return (mix, lls) if per_sample else mix


def anomaly_score(bank: SampleBank, X, key, *, mask=None,
                  n_sweeps: int = DEFAULT_LL_SWEEPS):
    """Per-row anomaly score = − mixture predictive log-likelihood."""
    return -predictive_loglik(bank, X, key, mask=mask, n_sweeps=n_sweeps)


@partial(jax.jit, static_argnames=("n_sweeps",))
def _naive_sample_rows(A, pi, active, sigma_x, X, key,
                       n_sweeps: int) -> Array:
    """Per-row joint ll for ONE sample the pre-§15 way: a cold-start
    uncollapsed Gibbs imputation of z* (exactly ``heldout_joint_loglik``'s
    inner loop) followed by the row-decomposed joint. One jit dispatch
    per sample — the serving anti-pattern the batched scorer replaces."""
    B, D = X.shape
    K = A.shape[0]
    Z = jnp.zeros((B, K), X.dtype)

    def body(Z, l):
        Z = uncollapsed_sweep(
            X, Z, A, pi, active, sigma_x, jax.random.fold_in(key, l)
        )
        return Z, None

    Z, _ = jax.lax.scan(body, Z, jnp.arange(n_sweeps))
    return _rows_joint_loglik(A, pi, active, sigma_x, X, Z,
                              jnp.ones_like(X))


def predictive_loglik_naive(bank: SampleBank, X, key, *,
                            n_sweeps: int = DEFAULT_LL_SWEEPS):
    """The un-batched baseline: a python loop dispatching one jitted
    per-sample scorer per bank sample — ensemble scoring as it existed
    before this subsystem (S sequential ``heldout_joint_loglik``-style
    evaluations), row-decomposed and logsumexp-mixed the same way.
    benchmarks/predict.py measures the batched scorer against THIS."""
    X = jnp.asarray(X)
    keys = jax.random.split(key, bank.S)
    out = []
    for s in range(bank.S):
        out.append(_naive_sample_rows(
            bank.A[s], bank.pi[s], bank.active[s], bank.sigma_x[s],
            X, keys[s], n_sweeps))
    lls = jnp.stack(out)
    return jax.scipy.special.logsumexp(lls, axis=0) - jnp.log(bank.S)


def make_sharded_scorer(bank: SampleBank, mesh, *, axis: str = "data",
                        n_sweeps: int = DEFAULT_LL_SWEEPS):
    """Row-sharded mixture scoring over a mesh ``axis`` — the serving
    analogue of the sampler's data axis: the bank is replicated, the
    batch rows are sharded, and each shard folds its axis index into
    the key so shards draw independent Gibbs streams.

    Returns ``score(X, key) -> (B,)`` (jitted; B must divide the axis
    size)."""
    from jax.sharding import PartitionSpec as P

    from repro import compat

    def block(X_p, key):
        k = jax.random.fold_in(key, compat.axis_index((axis,)))
        return predictive_loglik(bank, X_p, k, n_sweeps=n_sweeps)

    fn = compat.shard_map(
        block, mesh=mesh, in_specs=(P(axis), P()), out_specs=P(axis),
        check_vma=False,
    )
    return jax.jit(fn)


# --------------------------------------------------------------------------
# exact small-K enumeration oracle
# --------------------------------------------------------------------------


def exact_posterior(A, pi, active, sigma_x, X, mask=None):
    """Exact p(z* | x*_obs) by 2^K enumeration (K ≤ ENUM_MAX_K).

    Returns (marginals (B, K), log_marginal_lik (B,), cond_mean (B, D)):
    the exact Rao-Blackwell targets ``encode`` / ``predictive_loglik`` /
    ``impute`` estimate. Patterns that set an inactive bit are excluded
    (weight −inf), so the enumeration runs over the live set exactly."""
    A = jnp.asarray(A)
    K, D = A.shape
    if K > ENUM_MAX_K:
        raise ValueError(f"exact enumeration needs K <= {ENUM_MAX_K}, "
                         f"got {K}")
    X = jnp.asarray(X)
    m = _as_mask(X, mask)
    return _exact_posterior_jit(A, jnp.asarray(pi), jnp.asarray(active),
                                jnp.asarray(sigma_x), X, m)


@jax.jit
def _exact_posterior_jit(A, pi, active, sigma_x, X, mask):
    K, D = A.shape
    pats = ((jnp.arange(2**K)[:, None] >> jnp.arange(K)[None, :]) & 1
            ).astype(X.dtype)                                   # (P, K)
    valid = jnp.all(pats <= active[None, :] + 0.5, axis=1)
    p = jnp.clip(pi, 1e-6, 1.0 - 1e-6)
    prior = jnp.sum((pats * jnp.log(p)[None, :]
                     + (1.0 - pats) * jnp.log1p(-p)[None, :])
                    * active[None, :], axis=1)                  # (P,)
    means = pats @ (A * active[:, None])                        # (P, D)
    # masked Gaussian: sum over observed dims only
    R = X[None, :, :] - means[:, None, :]                       # (P, B, D)
    sse = jnp.sum(R * R * mask[None, :, :], axis=-1)            # (P, B)
    n_obs = jnp.sum(mask, axis=-1)[None, :]
    ll = (-0.5 * n_obs * ibm.LOG2PI - n_obs * jnp.log(sigma_x)
          - 0.5 * sse / sigma_x**2)
    logw = jnp.where(valid[:, None], prior[:, None] + ll, -jnp.inf)
    logZ = jax.scipy.special.logsumexp(logw, axis=0)            # (B,)
    w = jnp.exp(logw - logZ[None, :])                           # (P, B)
    marg = jnp.einsum("pb,pk->bk", w, pats)
    cond_mean = jnp.einsum("pb,pd->bd", w, means)
    return marg, logZ, cond_mean


# --------------------------------------------------------------------------
# canonical per-sample joint log-likelihoods (diagnostics re-exports)
# --------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("n_sweeps",))
def heldout_joint_loglik(
    X_test: Array,
    A: Array,
    pi: Array,
    active: Array,
    sigma_x: Array,
    key: Array,
    n_sweeps: int = DEFAULT_LL_SWEEPS,
) -> Array:
    """log P(X_test, Z_test | A, pi, sigma) with Z_test imputed by short
    uncollapsed Gibbs given ONE posterior draw (paper Fig. 1 metric).

    Identical algorithm + PRNG stream to the pre-§15 implementation in
    ``diagnostics`` (which now re-exports this); the residual scoring
    runs through the ``gaussian_sse`` kernel family. For ensemble
    (multi-sample) scoring use ``predictive_loglik`` — the logsumexp
    mixture over a SampleBank."""
    from repro.kernels.gaussian_sse import gaussian_sse

    N, D = X_test.shape
    K = A.shape[0]
    Z = jnp.zeros((N, K), X_test.dtype)

    def body(Z, l):
        Z = uncollapsed_sweep(
            X_test, Z, A, pi, active, sigma_x, jax.random.fold_in(key, l)
        )
        return Z, None

    Z, _ = jax.lax.scan(body, Z, jnp.arange(n_sweeps))
    n = X_test.size
    sse = gaussian_sse(X_test, Z, A, active)
    ll = (-0.5 * n * ibm.LOG2PI - n * jnp.log(sigma_x)
          - 0.5 * sse / sigma_x**2)
    return ll + ibm.z_prior_loglik(Z, pi, active)


def train_joint_loglik(
    X: Array, Z: Array, A: Array, pi: Array, active: Array, sigma_x: Array
) -> Array:
    """log P(X, Z | A, pi, sigma) on the training rows (monitoring)."""
    ll = ibm.uncollapsed_loglik(X, Z * active[None, :], A, sigma_x)
    return ll + ibm.z_prior_loglik(Z, pi, active)


# --------------------------------------------------------------------------
# numpy test oracle (NOT a production path)
# --------------------------------------------------------------------------


def joint_loglik_np(X, Z, A, pi, active, sigma_x, mask=None) -> np.ndarray:
    """Per-row joint log p(x_obs, z | sample) as an explicit float64
    numpy loop — the test oracle ``_rows_joint_loglik`` is checked
    against (tests/test_predict.py). Kept deliberately naive."""
    X = np.asarray(X, np.float64)
    Z = np.asarray(Z, np.float64)
    A = np.asarray(A, np.float64)
    pi = np.asarray(pi, np.float64)
    active = np.asarray(active, np.float64)
    sx = float(sigma_x)
    m = np.ones_like(X) if mask is None else np.asarray(mask, np.float64)
    B, D = X.shape
    out = np.zeros((B,), np.float64)
    log2pi = float(np.log(2.0 * np.pi))
    for b in range(B):
        ll = 0.0
        for d in range(D):
            if m[b, d] > 0.5:
                r = X[b, d] - float(
                    sum(Z[b, k] * active[k] * A[k, d]
                        for k in range(A.shape[0])))
                ll += -0.5 * log2pi - np.log(sx) - 0.5 * r * r / sx**2
        for k in range(A.shape[0]):
            if active[k] > 0.5:
                p = min(max(pi[k], 1e-6), 1.0 - 1e-6)
                ll += (Z[b, k] * np.log(p)
                       + (1.0 - Z[b, k]) * np.log1p(-p))
        out[b] = ll
    return out
