"""Composable sampler API: ``SamplerSpec`` + ``build_sampler`` (DESIGN.md §13).

One frozen, validated dataclass holds EVERY sampler knob — model sizes,
kernel dispatch (``L``, ``backend``, ``collapsed_backend``,
``chol_refresh``, ``k_live_buckets`` — occupancy-adaptive packing of the
collapsed carry, DESIGN.md §14), parallelism layout (``chains`` x
``data``, ``n_chains``, ``P``, ``sync``, ``stale_sync``) and run control
— and
``build_sampler(spec, hyp, X)`` turns it into a ``Sampler`` with a uniform
protocol:

    s = build_sampler(SamplerSpec(P=4, K_max=16, L=5), IBPHypers(), X)
    gs, st = s.init(jax.random.key(0))
    gs, st = s.step(gs, st)          # one full hybrid iteration
    gs, st = s.stale(gs, st)         # bounded-staleness pass (non-exact)
    ss = s.to_canonical(st)          # HybridShard, (C?, P, N_p, K) layout
    st = s.from_canonical(ss)        # back to the layout-native state

Parallelism is two ORTHOGONAL axes, not a driver enum:

    chains: "none" | "vmap" | "mesh"     x     data: "vmap" | "shardmap"

The historical driver names are degenerate points of that grid (see
``DRIVERS``): ``vmap`` = none x vmap, ``multichain`` = vmap x vmap,
``shardmap`` = none x shardmap, and the composed ``mesh`` = mesh x
shardmap — C chains x P data shards on a 2-D ``("chains", "data")``
mesh (runnable on CPU via ``--xla_force_host_platform_device_count``).
``chains="mesh"`` also composes with ``data="vmap"`` (real chain
parallelism, simulated data shards); only ``chains="vmap"`` x
``data="shardmap"`` is rejected — vmap of a collective program is not a
layout.

State crosses ``to_canonical`` in the canonical ``(C?, P, N_p, K)``
HybridShard layout, so checkpoints are interchangeable across every
layout with the same chain count (chainless <-> chainful restores are
rejected loudly by the driver; see runtime/driver.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .collapsed import COLLAPSED_BACKENDS, DEFAULT_REFRESH, K_LIVE_MODES
from .hybrid import (
    HybridShard,
    build_hybrid_fns,
    init_hybrid,
    init_multichain,
)
from .state import IBPHypers

CHAIN_MODES = ("none", "vmap", "mesh")
DATA_MODES = ("vmap", "shardmap")
SWEEP_BACKENDS = ("jnp", "pallas")
SYNC_MODES = ("staged", "fused")

# historical driver names -> (chains, data) axis modes
DRIVERS = {
    "vmap": ("none", "vmap"),
    "multichain": ("vmap", "vmap"),
    "shardmap": ("none", "shardmap"),
    "mesh": ("mesh", "shardmap"),
}


@dataclasses.dataclass(frozen=True)
class SamplerSpec:
    """All sampler knobs in one frozen, validated place.

    Invalid combinations fail at construction time with a ValueError —
    never silently downstream (a negative ``stale_sync`` used to skip the
    stale loop without a sound; a zero ``overflow_every`` used to crash
    the run loop with a bare ZeroDivisionError).
    """

    # ---- model / state sizes
    P: int = 4                 # data shards (processors p of the paper)
    K_max: int = 32            # instantiated-feature capacity
    K_tail: int = 8            # in-flight tail features on p'
    K_init: int = 4            # features seeded at init
    alpha: float = 3.0
    sigma_x: float = 1.0
    sigma_a: float = 1.0
    # ---- kernel dispatch
    L: int = 5                 # sub-iterations per master sync
    backend: str = "jnp"       # uncollapsed sweep: "jnp" | "pallas"
    collapsed_backend: str = "fast"  # tail row step: "ref"|"fast"|"pallas"
    chol_refresh: int = DEFAULT_REFRESH  # fast-path refactor cadence
    k_live_buckets: str = "on"  # occupancy-adaptive packing (DESIGN.md §14)
    # ---- parallelism layout (axes, not an enum)
    chains: str = "none"       # "none" | "vmap" | "mesh"
    data: str = "vmap"         # "vmap" | "shardmap"
    n_chains: int = 1          # C (chain axis size; 1 when chains="none")
    sync: str = "staged"       # "staged" | "fused" master sync (shardmap)
    stale_sync: int = 0        # bounded-staleness passes/iter (non-exact)
    # ---- run control (consumed by MCMCDriver, validated here)
    n_iters: int = 1000
    eval_every: int = 20
    ckpt_every: int = 100
    ckpt_dir: str = "artifacts/ckpt/ibp"
    overflow_every: int = 8    # overflow-detection cadence (host sync)
    k_tail_grow: int = 0       # adaptive K_tail: max automatic tail
    #                            doublings at checkpoint boundaries when
    #                            the tail-saturation counter fires
    #                            (0 = fixed K_tail; ceiling is K_max)
    seed: int = 0
    # ---- posterior-predictive harvest (SampleBank, DESIGN.md §15)
    harvest_every: int = 0     # harvest a posterior sample every this many
    #                            iterations (0 = off); chain-batched runs
    #                            harvest one sample per chain
    harvest_burn: float = 0.5  # fraction of the run discarded as burn-in
    #                            before harvesting starts
    bank_path: str = ""        # SampleBank npz ("" = <ckpt_dir>/bank.npz)

    def __post_init__(self):
        def bad(msg: str):
            raise ValueError(f"SamplerSpec: {msg}")

        if self.chains not in CHAIN_MODES:
            bad(f"chains={self.chains!r} not in {CHAIN_MODES}")
        if self.data not in DATA_MODES:
            bad(f"data={self.data!r} not in {DATA_MODES}")
        if (self.chains, self.data) == ("vmap", "shardmap"):
            bad("chains='vmap' cannot compose with data='shardmap' (vmap "
                "of a collective program is not a layout; use "
                "chains='mesh')")
        if self.n_chains < 1:
            bad(f"n_chains={self.n_chains} must be >= 1")
        if self.chains == "none" and self.n_chains != 1:
            bad(f"n_chains={self.n_chains} needs a chain axis; set "
                f"chains='vmap' or 'mesh' (driver='multichain'/'mesh')")
        if self.sync not in SYNC_MODES:
            bad(f"sync={self.sync!r} not in {SYNC_MODES}")
        if self.sync == "fused" and self.data != "shardmap":
            bad(f"sync='fused' is a collective schedule; data="
                f"{self.data!r} has no collectives (use data='shardmap')")
        if self.backend not in SWEEP_BACKENDS:
            bad(f"backend={self.backend!r} not in {SWEEP_BACKENDS}")
        if self.collapsed_backend not in COLLAPSED_BACKENDS:
            bad(f"collapsed_backend={self.collapsed_backend!r} not in "
                f"{COLLAPSED_BACKENDS}")
        if self.chol_refresh < 1:
            bad(f"chol_refresh={self.chol_refresh} must be >= 1")
        if self.k_live_buckets not in K_LIVE_MODES:
            bad(f"k_live_buckets={self.k_live_buckets!r} not in "
                f"{K_LIVE_MODES}")
        if self.P < 1:
            bad(f"P={self.P} must be >= 1")
        if self.L < 1:
            bad(f"L={self.L} must be >= 1")
        if self.K_max < 1 or self.K_tail < 1:
            bad(f"K_max={self.K_max}, K_tail={self.K_tail} must be >= 1")
        if self.K_tail > self.K_max:
            bad(f"K_tail={self.K_tail} exceeds K_max={self.K_max}: tail "
                f"promotion scatters into free instantiated slots, so a "
                f"tail wider than the capacity can try to place births "
                f"with no slot to hold them (at full occupancy every "
                f"promotion would silently drop)")
        if self.k_tail_grow < 0:
            bad(f"k_tail_grow={self.k_tail_grow} must be >= 0 "
                f"(0 disables adaptive K_tail growth)")
        if not 0 <= self.K_init <= self.K_max:
            bad(f"K_init={self.K_init} must be in [0, K_max={self.K_max}]")
        if self.stale_sync < 0:
            bad(f"stale_sync={self.stale_sync} must be >= 0 (a negative "
                f"value would silently skip the stale loop)")
        if self.overflow_every < 1:
            bad(f"overflow_every={self.overflow_every} must be >= 1")
        if self.n_iters < 1 or self.eval_every < 1 or self.ckpt_every < 1:
            bad(f"n_iters={self.n_iters}, eval_every={self.eval_every}, "
                f"ckpt_every={self.ckpt_every} must all be >= 1")
        if self.harvest_every < 0:
            bad(f"harvest_every={self.harvest_every} must be >= 0 "
                f"(0 disables harvesting)")
        if not 0.0 <= self.harvest_burn < 1.0:
            bad(f"harvest_burn={self.harvest_burn} must be in [0, 1) — a "
                f"burn fraction of the run, not an iteration count")

    # ---- derived views ----------------------------------------------------
    @property
    def driver(self) -> str:
        """Historical driver name for this layout (display/CLI)."""
        if self.chains == "mesh":
            return "mesh"
        if self.chains == "vmap":
            return "multichain"
        return "shardmap" if self.data == "shardmap" else "vmap"

    @property
    def chain_axis(self) -> bool:
        """Whether state leaves carry a leading chain axis."""
        return self.chains != "none"

    @property
    def devices_needed(self) -> int:
        """Real devices this layout requires (1 for pure-vmap layouts)."""
        c = self.n_chains if self.chains == "mesh" else 1
        p = self.P if self.data == "shardmap" else 1
        return c * p

    @classmethod
    def for_driver(cls, driver: str, **kw) -> "SamplerSpec":
        """Spec for a historical driver name (the DriverConfig shim path)."""
        if driver not in DRIVERS:
            raise ValueError(f"driver={driver!r} not in {tuple(DRIVERS)}")
        chains, data = DRIVERS[driver]
        return cls(chains=chains, data=data, **kw)

    def replace(self, **kw) -> "SamplerSpec":
        return dataclasses.replace(self, **kw)


class Sampler:
    """A built sampler: uniform init/step/stale/canonicalize protocol over
    every parallelism layout. Construct via ``build_sampler``.

    The native state ``st`` stays device-resident in the layout's hot
    format across the whole run loop; ``to_canonical``/``from_canonical``
    convert to/from the canonical ``(C?, P, N_p, K)`` HybridShard layout
    (used by checkpoints and eval) at cadence only.
    """

    def __init__(self, spec: SamplerSpec, hyp: IBPHypers, X: np.ndarray):
        self.spec = spec
        self.hyp = hyp
        X = np.asarray(X, np.float32)
        N = (X.shape[0] // spec.P) * spec.P
        if N == 0:
            raise ValueError(
                f"X has {X.shape[0]} rows; need at least P={spec.P}"
            )
        self.X_global = X[:N]
        self.N, self.D = N, X.shape[1]
        self.Xs = jnp.asarray(self.X_global.reshape(spec.P, N // spec.P,
                                                    self.D))
        self.chain_axis = spec.chain_axis
        self.mesh = self._make_mesh()
        self._flat = self.mesh is not None  # mesh-native (Z, Zt, ta) state
        self._fns = build_hybrid_fns(spec, hyp, N_global=self.N,
                                     mesh=self.mesh)
        self._Xn = self._place_data()

    # ---- construction helpers --------------------------------------------
    def _make_mesh(self):
        from repro.compat import make_mesh

        spec = self.spec
        if spec.data != "shardmap" and spec.chains != "mesh":
            return None
        need = spec.devices_needed
        if need > jax.device_count():
            raise ValueError(
                f"driver={spec.driver!r} needs {need} devices "
                f"({spec.n_chains if spec.chains == 'mesh' else 1} chains x "
                f"{spec.P if spec.data == 'shardmap' else 1} data shards), "
                f"have {jax.device_count()} (use "
                f"--xla_force_host_platform_device_count on CPU)"
            )
        if spec.chains == "mesh" and spec.data == "shardmap":
            return make_mesh((spec.n_chains, spec.P), ("chains", "data"))
        if spec.chains == "mesh":
            return make_mesh((spec.n_chains,), ("chains",))
        return make_mesh((spec.P,), ("data",))

    def _shardings(self):
        """(data-rows, chains, chains x data-rows) NamedShardings."""
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as PS

        m = self.mesh
        names = m.axis_names
        d = NamedSharding(m, PS("data")) if "data" in names else None
        c = NamedSharding(m, PS("chains")) if "chains" in names else None
        cd = (NamedSharding(m, PS("chains", "data"))
              if "chains" in names and "data" in names else None)
        return d, c, cd

    def _place_data(self):
        if not self._flat:
            return self.Xs
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as PS

        if self.spec.data == "shardmap":
            # (N, D) rows over the data axis, replicated over chains
            return jax.device_put(jnp.asarray(self.X_global),
                                  NamedSharding(self.mesh, PS("data")))
        # chains="mesh" x data="vmap": full (P, N_p, D) copy per chain
        return jax.device_put(self.Xs, NamedSharding(self.mesh, PS()))

    # ---- protocol ---------------------------------------------------------
    def init(self, key: jax.Array | None = None):
        """Fresh (gs, st) from the spec's init knobs; ``key`` defaults to
        ``jax.random.key(spec.seed)``."""
        spec = self.spec
        if key is None:
            key = jax.random.key(spec.seed)
        kw = dict(K_tail=spec.K_tail, alpha=spec.alpha, sigma_x=spec.sigma_x,
                  sigma_a=spec.sigma_a, K_init=spec.K_init)
        if self.chain_axis:
            gs, ss = init_multichain(key, self.Xs, spec.n_chains, spec.K_max,
                                     **kw)
        else:
            gs, ss = init_hybrid(key, self.Xs, spec.K_max, **kw)
        return gs, self.from_canonical(ss)

    def step(self, gs, st):
        """One full hybrid iteration (sub-iterations + master sync)."""
        if self._flat:
            gs2, Zf, Zt, ta = self._fns.step(self._Xn, gs, *st)
            return gs2, (Zf, Zt, ta)
        return self._fns.step(self._Xn, gs, st)

    def stale(self, gs, st):
        """One bounded-staleness pass: sub-iterations, no sync (non-exact)."""
        if self._flat:
            gs2, Zf, Zt, ta = self._fns.stale(self._Xn, gs, *st)
            return gs2, (Zf, Zt, ta)
        return self._fns.stale(self._Xn, gs, st)

    def to_canonical(self, st) -> HybridShard:
        """Native state -> canonical (C?, P, N_p, K) HybridShard."""
        if not self._flat:
            return st
        Zf, Zt, ta = st
        spec = self.spec
        P_, N_p = spec.P, self.N // spec.P
        if spec.data == "vmap":       # chains-mesh: already (C, P, N_p, ·)
            return HybridShard(Z=Zf, Z_tail=Zt, tail_active=ta)
        lead = (spec.n_chains,) if self.chain_axis else ()
        return HybridShard(
            Z=Zf.reshape(*lead, P_, N_p, Zf.shape[-1]),
            Z_tail=Zt.reshape(*lead, P_, N_p, Zt.shape[-1]),
            tail_active=ta,
        )

    def from_canonical(self, ss: HybridShard):
        """Canonical HybridShard -> native device-resident state."""
        if not self._flat:
            return ss
        d, c, cd = self._shardings()
        spec = self.spec
        if spec.data == "vmap":       # chains-mesh, simulated data shards
            return (jax.device_put(ss.Z, c),
                    jax.device_put(ss.Z_tail, c),
                    jax.device_put(ss.tail_active, c))
        *lead, P_, N_p, K = ss.Z.shape
        Kt = ss.Z_tail.shape[-1]
        row = cd if self.chain_axis else d
        return (
            jax.device_put(ss.Z.reshape(*lead, P_ * N_p, K), row),
            jax.device_put(ss.Z_tail.reshape(*lead, P_ * N_p, Kt), row),
            jax.device_put(ss.tail_active, row),
        )


def build_sampler(spec: SamplerSpec, hyp: IBPHypers | None = None,
                  X: Any = None) -> Sampler:
    """THE sampler factory: validated spec + hypers + data -> Sampler.

    Owns everything ``MCMCDriver._build_backend`` used to hand-roll:
    layout selection, mesh construction (with a loud device-count check),
    jit/vmap/shard_map wrapping, data placement, and the canonical <->
    native state conversions that keep checkpoints interchangeable
    across layouts.
    """
    if X is None:
        raise ValueError("build_sampler needs the data matrix X")
    return Sampler(spec, hyp or IBPHypers(), X)
