"""Collapsed Gibbs sampler for the linear-Gaussian IBP (Griffiths & Ghahramani).

This is the serial baseline the paper compares against (Fig. 1). A is fully
integrated out. For each row n we use the posterior-predictive form

    x_n | z_n, Z_-n, X_-n ~ N( z_n H_-,  sigma_x^2 (1 + z_n M_- z_n^T) I )

with M_- = (Z_-^T Z_- + (sx^2/sa^2) I)^{-1}, H_- = M_- Z_-^T X_-, which makes
each bit flip O(K + D) after one O(K^3 + K^2 D) per-row factorization.
New dishes use the exact truncated-Gibbs step: row-n singletons are dropped
and j_new ~ P(j | rest) ∝ Poisson(j; alpha/N) · lik(j) over j = 0..J_MAX
(lik(j) closed-form: new columns only add j·sa^2 to the predictive variance).

Everything is padded to K_max with an ``active`` mask. Complexity per sweep:
O(N (K^3 + K^2 D)) — the quadratic-in-N cost the paper attributes to the
collapsed sampler comes from K growing as alpha·log N plus serial row scans.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import math as ibm
from .state import IBPHypers, IBPState

Array = jax.Array

J_MAX = 4  # truncation for per-row new-dish draws (P(j>4 | alpha/N) is negligible)


def _log_poisson(j: Array, lam: Array) -> Array:
    return j * jnp.log(lam) - lam - jax.lax.lgamma(j + 1.0)


def _row_step(carry, n, *, X, N, D, birth="gibbs"):
    """Resample row n's bits + new dishes, collapsed.

    ``birth`` selects the new-dish move:
      * "gibbs" — exact truncated Gibbs over j ∈ 0..J_MAX (G&G; collapsed
        baseline).
      * "mh" — the paper's Metropolis-Hastings move for the hybrid tail:
        propose j ~ Poisson(alpha/N) and accept with the marginal-likelihood
        ratio (prior ∝ proposal, so they cancel). Out-of-capacity proposals
        are rejected.

    ``N`` is the GLOBAL number of observations — in the hybrid sampler the
    tail runs on processor p' with local rows but global-N priors
    ((m_k - Z_nk)/N and Poisson(alpha/N)), exactly as in the paper's
    pseudocode.
    """
    Z, active, ZtZ, ZtX, m, alpha, sx, sa, key = carry
    x_n = X[n]
    z = Z[n]
    # ---- remove row n from the sufficient statistics
    m_minus = m - z
    ZtZ_m = ZtZ - jnp.outer(z, z)
    ZtX_m = ZtX - jnp.outer(z, x_n)
    # drop row-n singletons (m_minus == 0 while z == 1): they are re-proposed
    # as part of the new-dish step (exact G&G scheme)
    singleton = active * (m_minus <= 0.5) * z
    z = z * (1.0 - singleton)
    active_m = active * (1.0 - (active * (m_minus <= 0.5)))  # live cols w/ support
    # ---- per-row factorization (exact; avoids rank-1 drift)
    ratio = (sx / sa) ** 2
    W = ibm.padded_W(ZtZ_m, active_m, ratio)
    M, _ = ibm.chol_inv_logdet(W)
    M = M * ibm.mask_outer(active_m)
    H = M @ (ZtX_m * active_m[:, None])  # (K, D) posterior mean map
    v = M @ z
    q = jnp.dot(z, v)
    mean = z @ H
    inv2s2 = 0.5 / (sx**2)

    K = Z.shape[1]
    key, kbits, kdish, kslot = jax.random.split(key, 4)
    uu = jnp.clip(jax.random.uniform(kbits, (K,), dtype=X.dtype), 1e-7, 1.0 - 1e-7)
    u = jnp.log(uu) - jnp.log1p(-uu)  # logit(U): accept z=1 iff logodds > u

    def bit_body(c, k):
        z, v, q, mean = c
        zk = z[k]
        Mk = M[:, k]
        Mkk = M[k, k]
        Hk = H[k]
        # state with bit k = 0
        v0 = v - zk * Mk
        q0 = q - zk * (2.0 * v[k] - Mkk)
        mean0 = mean - zk * Hk
        # state with bit k = 1
        v1 = v0 + Mk
        q1 = q0 + 2.0 * v0[k] + Mkk
        mean1 = mean0 + Hk
        s0 = 1.0 + q0
        s1 = 1.0 + q1
        r0 = x_n - mean0
        r1 = x_n - mean1
        ll0 = -0.5 * D * jnp.log(s0) - inv2s2 * jnp.dot(r0, r0) / s0
        ll1 = -0.5 * D * jnp.log(s1) - inv2s2 * jnp.dot(r1, r1) / s1
        mk = m_minus[k]
        logodds = jnp.log(jnp.maximum(mk, 1e-20)) - jnp.log(N - mk) + ll1 - ll0
        # sample; only live columns with support may flip
        may = (active_m[k] > 0) & (mk > 0.5)
        take1 = logodds > u[k]
        znk = jnp.where(may, take1.astype(z.dtype), z[k])
        pick1 = znk > 0.5
        v = jnp.where(pick1, v1, v0)
        q = jnp.where(pick1, q1, q0)
        mean = jnp.where(pick1, mean1, mean0)
        z = z.at[k].set(znk)
        return (z, v, q, mean), None

    (z, v, q, mean), _ = jax.lax.scan(bit_body, (z, v, q, mean), jnp.arange(K))

    # ---- new dishes, j = 0..J_MAX
    lam = alpha / N
    s = 1.0 + q
    r = x_n - mean
    rss = jnp.dot(r, r)
    js = jnp.arange(J_MAX + 1, dtype=X.dtype)
    rho = (sa / sx) ** 2
    s_j = s + js * rho
    ll_j = -0.5 * D * jnp.log(s_j) - inv2s2 * rss / s_j
    free = 1.0 - jnp.maximum(active_m, z)
    n_free = jnp.sum(free)
    if birth == "gibbs":
        # exact truncated Gibbs: j ~ ∝ Poisson(j; lam) lik(j)
        logits = _log_poisson(js, lam) + ll_j
        logits = jnp.where(js <= n_free, logits, -jnp.inf)
        j_new = jax.random.categorical(kdish, logits).astype(X.dtype)
    else:
        # paper's MH: propose j ~ Poisson(lam), accept w.p. lik(j)/lik(0)
        kprop, kacc = jax.random.split(kdish)
        j_prop = jax.random.poisson(kprop, lam).astype(X.dtype)
        ok = (j_prop <= jnp.minimum(float(J_MAX), n_free))
        j_idx = jnp.clip(j_prop, 0, J_MAX).astype(jnp.int32)
        dll = ll_j[j_idx] - ll_j[0]
        acc = jnp.log(jax.random.uniform(kacc, (), dtype=X.dtype)) < dll
        j_new = jnp.where(ok & acc, j_prop, 0.0)
    # place new dishes in the first j_new free slots
    free_rank = jnp.cumsum(free) * free  # 1-indexed rank among free slots
    newbits = ((free_rank >= 1.0) & (free_rank <= j_new)).astype(z.dtype)
    z = z + newbits
    active_new = jnp.maximum(active_m, newbits)

    # ---- add row n back
    m_new = m_minus * active_m + z  # dead/singleton cols contribute 0
    ZtZ_n = ZtZ_m * ibm.mask_outer(active_m) + jnp.outer(z, z)
    ZtX_n = ZtX_m * active_m[:, None] + jnp.outer(z, x_n)
    Z = Z.at[n].set(z)
    return (Z, active_new, ZtZ_n, ZtX_n, m_new, alpha, sx, sa, key), None


@partial(jax.jit, static_argnames=("hyp",))
def collapsed_sweep(state: IBPState, X: Array, hyp: IBPHypers) -> IBPState:
    """One full collapsed Gibbs sweep over all rows + hyperparameter updates."""
    N, D = X.shape
    Z, active = state.Z, state.active
    m = jnp.sum(Z * active[None, :], axis=0)
    ZtZ = (Z.T @ Z) * ibm.mask_outer(active)
    ZtX = (Z.T @ X) * active[:, None]
    key, ksweep, kalpha, ksx, ksa = jax.random.split(state.key, 5)

    body = partial(_row_step, X=X, N=float(N), D=D, birth="gibbs")
    carry = (Z, active, ZtZ, ZtX, m, state.alpha, state.sigma_x, state.sigma_a, ksweep)
    carry, _ = jax.lax.scan(body, carry, jnp.arange(N))
    Z, active, ZtZ, ZtX, m, alpha, sx, sa, _ = carry

    # prune columns that died during the sweep
    active = active * (m > 0.5)
    mask2 = ibm.mask_outer(active)
    ZtZ = ZtZ * mask2
    ZtX = ZtX * active[:, None]
    Z = Z * active[None, :]
    m = m * active
    k_plus = jnp.sum(active)

    # alpha | K+ ~ Gamma(a + K+, b + H_N)
    if hyp.resample_alpha:
        HN = ibm.harmonic(N)
        alpha = ibm.gamma_draw(kalpha, hyp.a_alpha + k_plus, hyp.b_alpha + HN)

    # sigma_x, sigma_a via random-walk MH on log-scale against collapsed lik
    if hyp.resample_sigmas:
        trXtX = jnp.sum(X * X)

        def cll(sx_, sa_):
            return ibm.collapsed_loglik(
                trXtX, ZtX, ZtZ, active, jnp.float32(N), D, sx_, sa_
            )

        def mh(key_, cur, other, which):
            kprop, kacc = jax.random.split(key_)
            prop = cur * jnp.exp(0.1 * jax.random.normal(kprop, (), dtype=cur.dtype))
            if which == "x":
                d = cll(prop, other) - cll(cur, other)
            else:
                d = cll(other, prop) - cll(other, cur)
            # log-normal RW: include log-scale Jacobian (log prop - log cur)
            d = d + jnp.log(prop) - jnp.log(cur)
            acc = jnp.log(jax.random.uniform(kacc, (), dtype=cur.dtype)) < d
            return jnp.where(acc, prop, cur)

        sx = mh(ksx, sx, sa, "x")
        sa = mh(ksa, sa, sx, "a")

    return IBPState(
        Z=Z, A=state.A, pi=state.pi, active=active, tail=state.tail,
        alpha=alpha, sigma_x=sx, sigma_a=sa, key=key,
        p_prime=state.p_prime, it=state.it + 1,
    )
