"""Collapsed Gibbs sampler for the linear-Gaussian IBP (Griffiths & Ghahramani).

This is the serial baseline the paper compares against (Fig. 1). A is fully
integrated out. For each row n we use the posterior-predictive form

    x_n | z_n, Z_-n, X_-n ~ N( z_n H_-,  sigma_x^2 (1 + z_n M_- z_n^T) I )

with M_- = (Z_-^T Z_- + (sx^2/sa^2) I)^{-1}, H_- = M_- Z_-^T X_-, which makes
each bit flip O(K + D) after the per-row posterior map is in hand.
New dishes use the exact truncated-Gibbs step: row-n singletons are dropped
and j_new ~ P(j | rest) ∝ Poisson(j; alpha/N) · lik(j) over j = 0..J_MAX
(lik(j) closed-form: new columns only add j·sa^2 to the predictive variance).

Everything is padded to K_max with an ``active`` mask.

Two row-step backends (DESIGN.md §12), selected by ``backend=``:

* ``"ref"``  — fresh O(K^3 + K^2 D) Cholesky factorization per row (the
  original sampler; kept as the exact oracle the fast path is tested
  against). Per sweep: O(N (K^3 + K^2 D)).
* ``"fast"`` — the factorization is CARRIED across the row scan and moved
  between rows by rank-one Cholesky up/downdates + Sherman–Morrison:
  remove-row = one downdate, singleton drop / new dish = diagonal
  identity swaps (the affected row/col of W is exactly ratio·e_k), add-row
  = one update; H moves by the matching rank-one corrections. O(K^2 + K D)
  algorithmic work per row. An exact refactorization every
  ``refresh_every`` rows plus a drift monitor (probe residual
  ‖M W p − p‖_∞ against the exactly maintained integer sufficient
  statistics, and the downdate's loss-of-positivity canary) force an
  early refresh when the carry degrades.
* ``"pallas"`` — the fast path with the K-sequential bit-flip recurrence
  executed by the ``kernels/collapsed_row`` Pallas kernel (VMEM-resident
  carry; compiled on TPU, interpret elsewhere).

There is ONE implementation of the carried row step: ``_packed_scan``,
which runs the carry PACKED to a block of B columns (the unified core,
DESIGN.md §12). Under ``k_live_buckets="on"`` (default) B is the live
K⁺ bucket — a power-of-two B ∈ {8, 16, ..., K_max} holding every live
column plus the lowest-index free slots, canonically ordered — so every
dense op costs O(B²+BD) instead of O(K_max²+K_max·D), and G = HHᵀ joins
the carry (moved by the rank-two corrections matching each H move) to
keep the strict O(K²+KD) row bound. ``collapsed_sweep`` picks the
bucket host-side per sweep (and re-packs mid-sweep when a feature birth
overflows the block — the overflowing row is re-run at the bigger
bucket, so decisions stay on the oracle's trajectory).
``k_live_buckets="off"`` is the TOP-BUCKET degenerate point of the same
ladder: the identical packed core at B = K_max with the G carry
disabled (``carry_g=False``), which is bitwise-identical to the
pre-unification unpacked carry (the packed flip recomputes G = HHᵀ per
row, exactly as the legacy ``_row_step_fast`` did). The in-jit entry
``collapsed_row_scan`` (the hybrid tail) runs the same core at the full
padded width — ``pack=True`` carries G, ``pack=False`` keeps the
legacy float path. Packing is a pure permutation + refresh: decisions
are ref-equivalent within a tiny boundary budget in every mode.

The MH new-dish move additionally reports a TAIL-SATURATION counter
(``n_sat``): rows whose accepted birth proposal was rejected only for
lack of free columns. The hybrid sampler aggregates it into
``HybridGlobal.tail_sat``, where it drives adaptive ``K_tail`` growth
(runtime/driver.py) — the finite-truncation bias of the tail becomes a
monitored, convergent quantity instead of a silent cap.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels.collapsed_row import collapsed_row_flip

from . import math as ibm
from .state import IBPHypers, IBPState

Array = jax.Array

J_MAX = 4  # truncation for per-row new-dish draws (P(j>4 | alpha/N) is negligible)

COLLAPSED_BACKENDS = ("ref", "fast", "pallas")
DEFAULT_REFRESH = 64    # exact refactorization cadence of the fast path
DEFAULT_DRIFT_TOL = 1e-2  # probe-residual threshold forcing an early refresh
PROBE_EVERY = 4         # drift-probe cadence within the refresh window
K_LIVE_MODES = ("on", "off")  # occupancy-adaptive packing knob values
PACK_HEADROOM = J_MAX   # free in-block slots guaranteed at (re)pack time
U_CHUNK_ROWS = 512      # packed-scan uniform buffer rows held on device:
#                         the hoisted per-row uniforms are generated
#                         block-wise at this granularity instead of all
#                         (N, K_max) at once, so long serial scans
#                         (harvest runs) keep O(U_CHUNK_ROWS * K) memory


def _log_poisson(j: Array, lam: Array) -> Array:
    return j * jnp.log(lam) - lam - jax.lax.lgamma(j + 1.0)


def _sample_dishes(kdish, q, mean, x_n, active_m, z, alpha, sx, sa, N, D,
                   birth, n_free_extra=0.0):
    """Shared new-dish move: returns (z', active', newbits, j_new, sat).

    ``birth`` selects the move:
      * "gibbs" — exact truncated Gibbs over j ∈ 0..J_MAX (G&G; collapsed
        baseline).
      * "mh" — the paper's Metropolis-Hastings move for the hybrid tail:
        propose j ~ Poisson(alpha/N) and accept with the marginal-likelihood
        ratio (prior ∝ proposal, so they cancel). Out-of-capacity proposals
        are rejected.

    ``sat`` is the tail-saturation flag: True iff an MH proposal that the
    likelihood ACCEPTED was rejected purely for lack of free columns
    (j ≤ J_MAX but j > n_free) — i.e. the row wanted more in-flight
    births than the truncation admits. Always False for "gibbs" (the
    collapsed baseline's capacity is K_max; its truncation is tracked by
    the driver's overflow machinery, not here).

    ``n_free_extra`` is the packed row step's out-of-block free-slot
    count: the draw must see the CANONICAL free capacity (what the
    oracle sees), even when only the in-block slots are placeable — the
    caller detects non-placeable births via ``j_new`` vs ``newbits``.
    """
    inv2s2 = 0.5 / (sx**2)
    lam = alpha / N
    s = 1.0 + q
    r = x_n - mean
    rss = jnp.dot(r, r)
    js = jnp.arange(J_MAX + 1, dtype=x_n.dtype)
    rho = (sa / sx) ** 2
    s_j = s + js * rho
    ll_j = -0.5 * D * jnp.log(s_j) - inv2s2 * rss / s_j
    free = 1.0 - jnp.maximum(active_m, z)
    n_free = jnp.sum(free) + n_free_extra
    if birth == "gibbs":
        # exact truncated Gibbs: j ~ ∝ Poisson(j; lam) lik(j)
        logits = _log_poisson(js, lam) + ll_j
        logits = jnp.where(js <= n_free, logits, -jnp.inf)
        j_new = jax.random.categorical(kdish, logits).astype(x_n.dtype)
        sat = jnp.zeros((), jnp.bool_)
    else:
        # paper's MH: propose j ~ Poisson(lam), accept w.p. lik(j)/lik(0)
        kprop, kacc = jax.random.split(kdish)
        j_prop = jax.random.poisson(kprop, lam).astype(x_n.dtype)
        ok = (j_prop <= jnp.minimum(float(J_MAX), n_free))
        j_idx = jnp.clip(j_prop, 0, J_MAX).astype(jnp.int32)
        dll = ll_j[j_idx] - ll_j[0]
        acc = jnp.log(jax.random.uniform(kacc, (), dtype=x_n.dtype)) < dll
        j_new = jnp.where(ok & acc, j_prop, 0.0)
        # capacity-bound rejection of an otherwise-accepted proposal: the
        # truncation (not the likelihood) vetoed these births
        sat = acc & (j_prop <= float(J_MAX)) & (j_prop > n_free)
    # place new dishes in the first j_new free slots
    free_rank = jnp.cumsum(free) * free  # 1-indexed rank among free slots
    newbits = ((free_rank >= 1.0) & (free_rank <= j_new)).astype(z.dtype)
    z = z + newbits
    active_new = jnp.maximum(active_m, newbits)
    return z, active_new, newbits, j_new, sat


def _row_step(carry, n, *, X, N, D, birth="gibbs"):
    """Resample row n's bits + new dishes, collapsed — the O(K^3) oracle.

    ``N`` is the GLOBAL number of observations — in the hybrid sampler the
    tail runs on processor p' with local rows but global-N priors
    ((m_k - Z_nk)/N and Poisson(alpha/N)), exactly as in the paper's
    pseudocode.

    The trailing ``n_sat`` carry element only accumulates the new-dish
    saturation flag — the sampling algebra and PRNG stream above it are
    the unchanged oracle.
    """
    Z, active, ZtZ, ZtX, m, alpha, sx, sa, key, n_sat = carry
    x_n = X[n]
    z = Z[n]
    # ---- remove row n from the sufficient statistics
    m_minus = m - z
    ZtZ_m = ZtZ - jnp.outer(z, z)
    ZtX_m = ZtX - jnp.outer(z, x_n)
    # drop row-n singletons (m_minus == 0 while z == 1): they are re-proposed
    # as part of the new-dish step (exact G&G scheme)
    singleton = active * (m_minus <= 0.5) * z
    z = z * (1.0 - singleton)
    active_m = active * (1.0 - (active * (m_minus <= 0.5)))  # live cols w/ support
    # ---- per-row factorization (exact; no carried state)
    ratio = (sx / sa) ** 2
    W = ibm.padded_W(ZtZ_m, active_m, ratio)
    M, _ = ibm.chol_inv_logdet(W)
    M = M * ibm.mask_outer(active_m)
    H = M @ (ZtX_m * active_m[:, None])  # (K, D) posterior mean map
    v = M @ z
    q = jnp.dot(z, v)
    mean = z @ H
    inv2s2 = 0.5 / (sx**2)

    K = Z.shape[1]
    key, kbits, kdish, kslot = jax.random.split(key, 4)
    uu = jnp.clip(jax.random.uniform(kbits, (K,), dtype=X.dtype), 1e-7, 1.0 - 1e-7)
    u = jnp.log(uu) - jnp.log1p(-uu)  # logit(U): accept z=1 iff logodds > u

    z, v, q, mean = collapsed_row_flip(
        M, H, x_n, z, v, q, mean, u, m_minus, active_m, N, inv2s2,
        flavor="jnp",
    )

    # ---- new dishes, j = 0..J_MAX
    z, active_new, _, _, sat = _sample_dishes(
        kdish, q, mean, x_n, active_m, z, alpha, sx, sa, N, D, birth
    )

    # ---- add row n back
    m_new = m_minus * active_m + z  # dead/singleton cols contribute 0
    ZtZ_n = ZtZ_m * ibm.mask_outer(active_m) + jnp.outer(z, z)
    ZtX_n = ZtX_m * active_m[:, None] + jnp.outer(z, x_n)
    Z = Z.at[n].set(z)
    return (Z, active_new, ZtZ_n, ZtX_n, m_new, alpha, sx, sa, key,
            n_sat + sat.astype(n_sat.dtype)), None


def _exact_factor(ZtZ, ZtX, active, ratio):
    """O(K^3 + K^2 D) exact (Lt, M, H) from the sufficient statistics."""
    W = ibm.padded_W(ZtZ, active, ratio)
    L, M = ibm.chol_inv(W)
    M = M * ibm.mask_outer(active)
    H = M @ (ZtX * active[:, None])
    return L.T, M, H


class _PackedCarry(NamedTuple):
    """Row-scan carry of the unified packed fast backend (DESIGN.md §12).
    Everything feature-indexed lives on the K_live block (size B,
    canonical columns ``cols`` ascending); only Z stays in the canonical
    layout (rows are gathered/scattered through ``cols`` per row).
    When ``carry_g`` is on, G = HHᵀ joins the carry — moved by the
    rank-two corrections matching each Sherman–Morrison H move instead
    of the per-row O(K²D) recompute in the packed flip; ``n``/``ovf``
    drive the early-exit while_loop (a birth that cannot be placed
    inside the block stops the scan BEFORE committing its row, so the
    host can repack and resume bitwise)."""

    n: Array          # () int32 — next row to process
    Z: Array          # (n_rows, K_canonical)
    active: Array     # (B,)
    ZtZ: Array        # (B, B)
    ZtX: Array        # (B, D)
    m: Array          # (B,)
    Lt: Array         # (B, B)
    M: Array          # (B, B)
    H: Array          # (B, D)
    G: Array          # (B, B) = H Hᵀ (carried; () placeholder when off)
    since: Array
    n_refresh: Array
    n_sat: Array      # () int32 — capacity-vetoed accepted births so far
    ovf: Array        # () bool — birth did not fit the packed block
    ubuf: Array       # (u_chunk, K_canonical) — current uniform block
    ubase: Array      # () int32 — first row-offset covered by ``ubuf``


@partial(jax.jit, static_argnames=("N", "birth", "B", "refresh_every",
                                   "drift_tol", "flip_flavor",
                                   "u_chunk_rows", "carry_g"))
def _packed_scan(
    Z, active, ZtZ, ZtX, m, X, key, alpha, sx, sa, start_row, *,
    N: float, birth: str, B: int, refresh_every: int,
    drift_tol: float = DEFAULT_DRIFT_TOL, flip_flavor: str = "packed",
    u_chunk_rows: int = U_CHUNK_ROWS, carry_g: bool = True,
):
    """Packed row scan from ``start_row`` to the end of X — or to the
    first birth that does not fit the K_live block. THE single
    implementation of the carried collapsed row step (DESIGN.md §12).

    Inputs and outputs are CANONICAL (K_max-padded); the block gather at
    entry, the exact refactorization of the packed factor (+ G), and the
    scatter back at exit happen inside this one jitted function, so a
    bucket change costs exactly one repack + refresh. Returns
    (Z, active, ZtZ, ZtX, m, n_refresh, n_sat, key, ovf_row): ``ovf_row``
    is -1 when the scan completed, else the first UNPROCESSED row — all
    rows before it are committed, and the caller resumes from it after
    repacking (``ibm.pick_bucket`` guarantees the pending birth then
    fits, so every resume makes progress). ``n_sat`` counts committed
    rows whose accepted MH birth was vetoed by capacity (always 0 for
    ``birth="gibbs"``).

    ``carry_g=False`` is the TOP-BUCKET degenerate mode (B = K_max, the
    ``k_live_buckets="off"`` sweep): the G carry is skipped entirely and
    the packed flip recomputes G = HHᵀ per row, which reproduces the
    pre-unification unpacked carry BITWISE — the G carry is the only
    float-path difference between the two.

    Decision equivalence: the block holds every live column plus the
    lowest-index free slots in canonical order, the per-row uniform draw
    keeps the oracle's (K_canonical,) shape (gathered through ``cols``),
    and the new-dish draw sees the canonical free capacity — so the
    only packed-vs-oracle differences are float-rounding boundary
    events, in every mode.
    """
    n_rows, D = X.shape
    K_can = Z.shape[1]
    cols, min_out = ibm.block_select(active, B)
    n_out_free = float(K_can - B)  # out-of-block slots are free by invariant
    active_p = active[cols]
    ZtZ_p = ZtZ[cols][:, cols]
    ZtX_p = ZtX[cols]
    m_p = m[cols]
    ratio = (sx / sa) ** 2
    Lt0, M0, H0 = _exact_factor(ZtZ_p, ZtX_p, active_p, ratio)
    # the mean-form pallas flip never consumes G — skip the whole G carry
    # (moves, refresh rebuild, probe term) at trace time for that flavor
    carry_g = carry_g and flip_flavor != "pallas"
    G0 = H0 @ H0.T if carry_g else jnp.zeros((), X.dtype)
    inv2s2 = 0.5 / (sx**2)

    # ---- hoist the oracle's per-row PRNG out of the serial loop: the
    # split chain is batched into one scan — bitwise the same stream,
    # but the K-wide generation no longer serializes with the row steps.
    # The chain is POSITIONAL in rows-processed-this-segment (the oracle
    # splits once per processed row, regardless of row index), so every
    # lookup below is relative to start_row; chain_data[j] = the carry
    # key after j processed rows, making the resume-after-overflow key
    # chain_data[ovf_row - start_row].
    #
    # The (K_canonical,)-wide uniform EXPANSION is chunked: only
    # ``u_chunk`` rows of logit-uniforms are resident at a time, refilled
    # inside the loop when the row index crosses the block (positional
    # key chain => block-wise generation is bitwise identical to the
    # all-rows hoist). The O(n_rows) buffers that remain — the key chain
    # and the per-row dish keys — are a few words per row, so very large
    # serial N no longer materializes an (N, K_max) buffer.
    #
    # ``chunked`` is a TRACE-TIME branch: when one block covers the scan
    # the in-loop refill cond is not traced at all. That matters beyond
    # tidiness — under a chain-vmapped caller lax.cond lowers to select
    # (both branches execute every iteration), which would turn the
    # amortized refill into a full block generation PER ROW. In-jit /
    # vmapped callers (the hybrid tail) therefore pass
    # u_chunk_rows >= n_rows (their K_canonical is the small K_tail, so
    # the full hoist is cheap); only the host-dispatched serial sweep —
    # never vmapped — takes the chunked path.
    sr = jnp.asarray(start_row, jnp.int32)
    u_chunk = min(u_chunk_rows, n_rows)
    chunked = u_chunk < n_rows
    j_cap = jnp.asarray(n_rows - u_chunk, jnp.int32)

    def key_step(k, _):
        k2, kbits, kdish, _kslot = jax.random.split(k, 4)
        return k2, (jax.random.key_data(k2), jax.random.key_data(kbits),
                    kdish)

    _, (chain_next, kbits_data, kdish_all) = jax.lax.scan(
        key_step, key, None, length=n_rows)
    chain_data = jnp.concatenate(
        [jax.random.key_data(key)[None], chain_next])

    def gen_u(base):
        """Logit-uniform block for row offsets [base, base + u_chunk)."""
        kb = jax.lax.dynamic_slice_in_dim(kbits_data, base, u_chunk, 0)
        uu = jax.vmap(
            lambda kd: jax.random.uniform(
                jax.random.wrap_key_data(kd), (K_can,), dtype=X.dtype)
        )(kb)
        uu = jnp.clip(uu, 1e-7, 1.0 - 1e-7)
        return jnp.log(uu) - jnp.log1p(-uu)

    # single-block case: the whole buffer is a loop-closure constant and
    # the carry's ubuf is an empty placeholder (cond-free hot loop)
    u_all = None if chunked else gen_u(jnp.zeros((), jnp.int32))

    def body(c: _PackedCarry) -> _PackedCarry:
        n = c.n
        active, ZtZ, ZtX, m = c.active, c.ZtZ, c.ZtX, c.m
        Lt, M, H, G = c.Lt, c.M, c.H, c.G
        x_n = X[n]
        z_old = c.Z[n][cols]
        # ---- remove row n (Sherman–Morrison; mirrors _row_step_fast on
        # the packed block — see that function for the algebra notes)
        m_minus = m - z_old
        zu = z_old * active
        w = M @ zu
        p_down = Lt @ w
        down_ok = jnp.all(1.0 - jnp.cumsum(p_down * p_down) > 1e-12)
        gamma = jnp.dot(zu, w)
        delta_s = jnp.maximum(1.0 - gamma, 1e-6)
        zH = zu @ H
        wr = w / jnp.sqrt(delta_s)
        wd = w / delta_s
        b_rm = zH - x_n
        M1 = M + jnp.outer(wr, wr)
        H1 = H + jnp.outer(wd, b_rm)
        # pre-move H, same as the SM read
        G1 = ibm.g_rank1(G, H, wd, b_rm) if carry_g else G
        drop = active * (m_minus <= 0.5)
        z = z_old * (1.0 - drop)
        active_m = active * (1.0 - drop)
        has_drop = jnp.any(drop > 0.5)
        # unconditional drop masking: on the no-drop path the carry
        # already holds exact zeros on inactive rows/cols, so the
        # multiply is a bitwise no-op — cheaper than a branch at block
        # sizes (the unpacked path gates this; at B ≤ K_max the cond's
        # dispatch costs more than B² multiplies)
        keep2 = ibm.mask_outer(active_m)
        M1 = M1 * keep2
        H1 = H1 * active_m[:, None]
        if carry_g:
            G1 = G1 * keep2

        # ---- drift monitor: the M probe of the unpacked path, plus the
        # G-consistency residual ‖G p − H(Hᵀp)‖∞ (relative to max|G|) so
        # the carried G is covered by the same monitor (DESIGN.md §14)
        def do_probe(_):
            tm = ZtZ @ active_m - z_old * jnp.dot(z_old, active_m)
            probe_t = active_m * tm + ratio * active_m
            d_m = jnp.max(jnp.abs(M1 @ probe_t - active_m))
            if not carry_g:
                return d_m
            d_g = jnp.max(jnp.abs(G1 @ active_m - H1 @ (active_m @ H1)))
            d_g = d_g / (1.0 + jnp.max(jnp.abs(G1)))
            return jnp.maximum(d_m, d_g)

        drift = jax.lax.cond(
            c.since % PROBE_EVERY == 0, do_probe,
            lambda _: jnp.zeros((), X.dtype), None,
        )
        need = ((c.since >= refresh_every - 1) | (~down_ok)
                | (~(drift <= drift_tol)))

        def do_refresh(_):
            ZtZ_m = ZtZ - jnp.outer(z_old, z_old)
            ZtX_m = ZtX - jnp.outer(z_old, x_n)
            L2, M2 = ibm.chol_inv(ibm.padded_W(ZtZ_m, active_m, ratio))
            M2 = M2 * ibm.mask_outer(active_m)
            H2 = M2 @ (ZtX_m * active_m[:, None])
            return L2.T, M2, H2, (H2 @ H2.T if carry_g else G)

        Lt_rm, M1, H1, G1 = jax.lax.cond(
            need, do_refresh, lambda _: (Lt, M1, H1, G1), None
        )
        since = jnp.where(need, 0, c.since + 1)
        n_refresh = c.n_refresh + need.astype(c.n_refresh.dtype)

        # ---- bit flips: the oracle's PRNG stream (canonical-width
        # uniforms, generated block-wise, gathered onto the block). The
        # refill is deterministic in the row offset, so an overflow
        # retry re-reads the identical draws even across the refill.
        j = n - sr
        if chunked:
            def refill(_):
                base = jnp.minimum((j // u_chunk) * u_chunk, j_cap)
                return gen_u(base), base

            ubuf, ubase = jax.lax.cond(
                j >= c.ubase + u_chunk, refill,
                lambda _: (c.ubuf, c.ubase), None,
            )
            u = ubuf[j - ubase][cols]
        else:
            ubuf, ubase = c.ubuf, c.ubase
            u = u_all[j][cols]
        kdish = kdish_all[j]

        def vqm_closed(_):
            gd = gamma / delta_s
            return wd, gd, zH + gd * (zH - x_n)

        def vqm_matvec(_):
            v = M1 @ z
            return v, jnp.dot(z, v), z @ H1

        v, q, mean = jax.lax.cond(
            has_drop | need, vqm_matvec, vqm_closed, None
        )
        z, v, q, mean = collapsed_row_flip(
            M1, H1, x_n, z, v, q, mean, u, m_minus, active_m, N, inv2s2,
            flavor=flip_flavor, G=G1 if carry_g else None,
        )

        # ---- new dishes: canonical free capacity; placement must stay
        # inside the block AND below every out-of-block index to match
        # the oracle's first-free-slot rule — otherwise flag + bail
        z2, active_new, newbits, j_new, sat = _sample_dishes(
            kdish, q, mean, x_n, active_m, z, alpha, sx, sa, N, D, birth,
            n_free_extra=n_out_free,
        )
        top_col = jnp.max(jnp.where(newbits > 0.5, cols, -1))
        birth_ovf = (jnp.sum(newbits) < j_new) | (top_col >= min_out)

        # ---- add row n back (same gating as the unpacked fast path)
        m_new = m_minus * active_m + z2
        changed = (
            need | jnp.any(z2 != z_old) | jnp.any(active_new != active)
        )

        def stats_moved(_):
            def masked(_):
                return ((ZtZ - jnp.outer(z_old, z_old))
                        * ibm.mask_outer(active_m) + jnp.outer(z2, z2),
                        (ZtX - jnp.outer(z_old, x_n)) * active_m[:, None]
                        + jnp.outer(z2, x_n))

            def fused(_):
                return (ZtZ + jnp.outer(z2, z2) - jnp.outer(z_old, z_old),
                        ZtX + jnp.outer(z2 - z_old, x_n))

            return jax.lax.cond(has_drop, masked, fused, None)

        ZtZ_n, ZtX_n = jax.lax.cond(
            changed | has_drop, stats_moved, lambda _: (ZtZ, ZtX), None
        )

        def apply_moves(_):
            Lt1 = jax.lax.cond(
                need,
                lambda __: Lt_rm,
                lambda __: ibm.chol_rank1_downdate_t(Lt, p_down)[0],
                None,
            )

            def diag_swaps(ops):
                Lt1, M1, H1, G1 = ops
                keep2 = ibm.mask_outer(active_m)
                Lt1 = Lt1 * keep2 + jnp.diag(1.0 - active_m)
                Lt1 = Lt1 + jnp.diag(newbits * (jnp.sqrt(ratio) - 1.0))
                M1b = M1 + jnp.diag(newbits / ratio)
                H1b = H1 * (1.0 - newbits)[:, None]
                G1b = (G1 * ibm.mask_outer(1.0 - newbits) if carry_g
                       else G1)
                return Lt1, M1b, H1b, G1b

            Lt1, M1b, H1b, G1b = jax.lax.cond(
                has_drop | jnp.any(newbits > 0.5), diag_swaps,
                lambda ops: ops, (Lt1, M1, H1, G1),
            )
            w2 = M1b @ z2
            Lt2 = ibm.chol_rank1_update_t(Lt1, Lt1 @ w2)
            d2 = 1.0 + jnp.dot(z2, w2)
            w2r = w2 / jnp.sqrt(d2)
            M2 = M1b - jnp.outer(w2r, w2r)
            b_add = x_n - z2 @ H1b
            H2 = H1b + jnp.outer(w2 / d2, b_add)
            G2 = ibm.g_rank1(G1b, H1b, w2 / d2, b_add) if carry_g else G1b
            return Lt2, M2, H2, G2

        Lt_n, M_n, H_n, G_n = jax.lax.cond(
            changed, apply_moves, lambda _: (Lt, M, H, G), None
        )
        # on birth overflow: keep the pre-row carry verbatim (the key
        # chain is positional — the retry re-reads the identical draws).
        # Elementwise selects, NOT a lax.cond over the whole carry: a
        # branch returning every buffer (Z included) forces whole-buffer
        # copies per row, which dwarfs the packed savings.
        def sel(old, new_):
            return jnp.where(birth_ovf, old, new_)

        return _PackedCarry(
            n=n + (~birth_ovf).astype(jnp.int32),
            # overflow writes the just-gathered bits back: an in-place no-op
            Z=c.Z.at[n, cols].set(sel(z_old, z2)),
            active=sel(active, active_new),
            ZtZ=sel(ZtZ, ZtZ_n), ZtX=sel(ZtX, ZtX_n), m=sel(m, m_new),
            Lt=sel(Lt, Lt_n), M=sel(M, M_n), H=sel(H, H_n), G=sel(G, G_n),
            since=sel(c.since, since),
            n_refresh=sel(c.n_refresh, n_refresh),
            n_sat=sel(c.n_sat, c.n_sat + sat.astype(c.n_sat.dtype)),
            ovf=birth_ovf,
            # no sel(): the refill is positional in j, and an overflow
            # exits the loop — the host resumes with a fresh scan call
            ubuf=ubuf, ubase=ubase,
        )

    carry0 = _PackedCarry(
        n=jnp.asarray(start_row, jnp.int32), Z=Z, active=active_p,
        ZtZ=ZtZ_p, ZtX=ZtX_p, m=m_p, Lt=Lt0, M=M0, H=H0, G=G0,
        since=jnp.zeros((), jnp.int32), n_refresh=jnp.zeros((), jnp.int32),
        n_sat=jnp.zeros((), jnp.int32),
        ovf=jnp.zeros((), jnp.bool_),
        ubuf=(gen_u(jnp.zeros((), jnp.int32)) if chunked
              else jnp.zeros((0, K_can), X.dtype)),
        ubase=jnp.zeros((), jnp.int32),
    )
    out = jax.lax.while_loop(
        lambda c: (c.n < n_rows) & (~c.ovf), body, carry0
    )
    # scatter the block back to the canonical layout (out-of-block slots
    # are free: zero stats by the block invariant)
    dt = X.dtype
    active_c = jnp.zeros((K_can,), dt).at[cols].set(out.active)
    ZtZ_c = jnp.zeros((K_can, K_can), dt).at[cols[:, None],
                                             cols[None, :]].set(out.ZtZ)
    ZtX_c = jnp.zeros((K_can, D), dt).at[cols].set(out.ZtX)
    m_c = jnp.zeros((K_can,), dt).at[cols].set(out.m)
    ovf_row = jnp.where(out.ovf, out.n, -1)
    key_out = jax.random.wrap_key_data(chain_data[out.n - sr])
    return (out.Z, active_c, ZtZ_c, ZtX_c, m_c, out.n_refresh, out.n_sat,
            key_out, ovf_row)


def collapsed_row_scan(
    Z: Array,
    active: Array,
    ZtZ: Array,
    ZtX: Array,
    m: Array,
    X: Array,
    key: Array,
    alpha: Array,
    sx: Array,
    sa: Array,
    *,
    N: float,
    birth: str = "gibbs",
    backend: str = "ref",
    refresh_every: int = DEFAULT_REFRESH,
    drift_tol: float = DEFAULT_DRIFT_TOL,
    pack: bool = False,
    u_chunk_rows: int | None = None,
) -> tuple[Array, Array, Array, Array, Array, Array, Array]:
    """Scan the collapsed row step over every row of ``X``.

    The shared entry point of the serial baseline (``collapsed_sweep``)
    and the hybrid tail (``hybrid._tail_sub_iteration``). Returns
    (Z, active, ZtZ, ZtX, m, n_refresh, n_sat); ``n_refresh`` counts
    exact refactorizations (cadence + monitor, 0 on the ref backend)
    and ``n_sat`` the capacity-vetoed accepted MH births (the tail-
    saturation signal; 0 for ``birth="gibbs"``).

    The fast/pallas backends run the ONE packed core at the full padded
    width (a static in-jit bucket: B = K; the bucketed B < K_max
    dispatch needs the host — ``collapsed_sweep``). ``pack`` selects the
    float path: ``True`` carries G = HHᵀ, removing the per-row O(K²D)
    GEMM from the packed flip (the hybrid tail's win); ``False`` keeps
    the legacy unpacked float path (G recomputed per row) — bitwise the
    pre-unification ``k_live_buckets="off"`` carry. Ignored for
    ``backend="ref"``.

    ``u_chunk_rows=None`` keeps the historical defaults: the full
    (n_rows, K) uniform hoist for ``pack=True`` and the chunked
    U_CHUNK_ROWS buffer otherwise. The chunked refill is safe only for
    host-dispatched serial callers — in-jit / vmapped callers (the
    hybrid tail) MUST pass ``u_chunk_rows >= n_rows``: under vmap the
    chunk-refill lax.cond lowers to select and regenerates a whole
    block per row.
    """
    if backend not in COLLAPSED_BACKENDS:
        raise ValueError(f"backend={backend!r} not in {COLLAPSED_BACKENDS}")
    n_rows, D = X.shape
    if backend == "ref":
        body = partial(_row_step, X=X, N=N, D=D, birth=birth)
        carry = (Z, active, ZtZ, ZtX, m, alpha, sx, sa, key,
                 jnp.zeros((), jnp.int32))
        carry, _ = jax.lax.scan(body, carry, jnp.arange(n_rows))
        Z, active, ZtZ, ZtX, m = carry[:5]
        return Z, active, ZtZ, ZtX, m, jnp.zeros((), jnp.int32), carry[9]
    # full-width block: overflow is impossible (no out-of-block slots)
    Z, active, ZtZ, ZtX, m, n_refresh, n_sat, _, _ = _packed_scan(
        Z, active, ZtZ, ZtX, m, X, key, alpha, sx, sa, 0,
        N=N, birth=birth, B=Z.shape[1], refresh_every=refresh_every,
        drift_tol=drift_tol,
        flip_flavor="pallas" if backend == "pallas" else "packed",
        u_chunk_rows=(u_chunk_rows if u_chunk_rows is not None
                      else n_rows if pack
                      else min(U_CHUNK_ROWS, n_rows)),
        carry_g=pack,
    )
    return Z, active, ZtZ, ZtX, m, n_refresh, n_sat


def _finish_sweep(state, X, hyp, Z, active, ZtZ, ZtX, m,
                  key, kalpha, ksx, ksa) -> IBPState:
    """Post-scan pruning + hyperparameter updates shared by every sweep
    path (the jitted unpacked sweep traces it inline; the host-bucketed
    packed sweep calls the jitted wrapper below)."""
    N, D = X.shape
    alpha, sx, sa = state.alpha, state.sigma_x, state.sigma_a

    # prune columns that died during the sweep
    active = active * (m > 0.5)
    mask2 = ibm.mask_outer(active)
    ZtZ = ZtZ * mask2
    ZtX = ZtX * active[:, None]
    Z = Z * active[None, :]
    m = m * active
    k_plus = jnp.sum(active)

    # alpha | K+ ~ Gamma(a + K+, b + H_N)
    if hyp.resample_alpha:
        HN = ibm.harmonic(N)
        alpha = ibm.gamma_draw(kalpha, hyp.a_alpha + k_plus, hyp.b_alpha + HN)

    # sigma_x, sigma_a via random-walk MH on log-scale against collapsed lik
    if hyp.resample_sigmas:
        trXtX = jnp.sum(X * X)

        def cll(sx_, sa_):
            return ibm.collapsed_loglik(
                trXtX, ZtX, ZtZ, active, jnp.float32(N), D, sx_, sa_
            )

        def mh(key_, cur, other, which):
            kprop, kacc = jax.random.split(key_)
            prop = cur * jnp.exp(0.1 * jax.random.normal(kprop, (), dtype=cur.dtype))
            if which == "x":
                d = cll(prop, other) - cll(cur, other)
            else:
                d = cll(other, prop) - cll(other, cur)
            # log-normal RW: include log-scale Jacobian (log prop - log cur)
            d = d + jnp.log(prop) - jnp.log(cur)
            acc = jnp.log(jax.random.uniform(kacc, (), dtype=cur.dtype)) < d
            return jnp.where(acc, prop, cur)

        sx = mh(ksx, sx, sa, "x")
        sa = mh(ksa, sa, sx, "a")

    return IBPState(
        Z=Z, A=state.A, pi=state.pi, active=active, tail=state.tail,
        alpha=alpha, sigma_x=sx, sigma_a=sa, key=key,
        p_prime=state.p_prime, it=state.it + 1,
    )


_finish_sweep_jit = jax.jit(_finish_sweep, static_argnames=("hyp",))


@partial(jax.jit, static_argnames=("hyp", "backend", "refresh_every"))
def _collapsed_sweep_jit(
    state: IBPState,
    X: Array,
    hyp: IBPHypers,
    backend: str = "ref",
    refresh_every: int = DEFAULT_REFRESH,
) -> IBPState:
    """One fully-jitted collapsed sweep (ref, or the unified fast/pallas
    core at the TOP bucket: B = K_max, legacy no-G float path)."""
    N, D = X.shape
    Z, active = state.Z, state.active
    m, ZtZ, ZtX, _ = _sweep_stats(Z, active, X)
    key, ksweep, kalpha, ksx, ksa = jax.random.split(state.key, 5)

    Z, active, ZtZ, ZtX, m, _, _ = collapsed_row_scan(
        Z, active, ZtZ, ZtX, m, X, ksweep,
        state.alpha, state.sigma_x, state.sigma_a,
        N=float(N), birth="gibbs", backend=backend,
        refresh_every=refresh_every,
    )
    return _finish_sweep(state, X, hyp, Z, active, ZtZ, ZtX, m,
                         key, kalpha, ksx, ksa)


def _sweep_stats(Z, active, X):
    """Exact sweep-entry sufficient statistics (+ K⁺ for bucket choice)."""
    m = jnp.sum(Z * active[None, :], axis=0)
    ZtZ = (Z.T @ Z) * ibm.mask_outer(active)
    ZtX = (Z.T @ X) * active[:, None]
    return m, ZtZ, ZtX, jnp.sum(active)


@partial(jax.jit, static_argnames=("hyp", "backend", "refresh_every", "B"))
def _packed_sweep_jit(state, X, hyp, backend, refresh_every, B):
    """One FUSED packed sweep attempt at bucket ``B``: stats + packed
    scan from row 0 + hyper-update finish, all in one dispatch.

    Returns (finished_state, raw_segment_outputs, ovf_row). On the
    common no-overflow sweep the host uses ``finished_state`` directly —
    one dispatch plus two scalar fetches (the pre-sweep occupancy for
    the bucket choice and ``ovf_row``), nearly the dispatch profile of
    the unpacked jitted sweep. On the rare birth overflow the finish is
    discarded and the host resumes segment-wise from
    ``raw_segment_outputs`` (the speculative finish is the only wasted
    work).
    """
    N, D = X.shape
    m, ZtZ, ZtX, _ = _sweep_stats(state.Z, state.active, X)
    key, ksweep, kalpha, ksx, ksa = jax.random.split(state.key, 5)
    Z, active, ZtZ2, ZtX2, m2, _, _, ksweep2, ovf_row = _packed_scan(
        state.Z, state.active, ZtZ, ZtX, m, X, ksweep,
        state.alpha, state.sigma_x, state.sigma_a, 0,
        N=float(N), birth="gibbs", B=B, refresh_every=refresh_every,
        flip_flavor="pallas" if backend == "pallas" else "packed",
    )
    done = _finish_sweep(state, X, hyp, Z, active, ZtZ2, ZtX2, m2,
                         key, kalpha, ksx, ksa)
    raw = (Z, active, ZtZ2, ZtX2, m2, ksweep2, key, kalpha, ksx, ksa)
    return done, raw, ovf_row


def _collapsed_sweep_packed(
    state: IBPState,
    X: Array,
    hyp: IBPHypers,
    backend: str,
    refresh_every: int,
    seg_log: list | None = None,
) -> IBPState:
    """Host-bucketed packed sweep (DESIGN.md §14).

    The host picks the K_live bucket — the smallest power-of-two bucket
    holding K⁺ + PACK_HEADROOM (``ibm.pick_bucket``) — and runs ONE
    fused jitted sweep at that static width (``_packed_sweep_jit``). A
    birth overflowing the block returns early with the finish discarded;
    the host then re-picks the bucket from the post-segment occupancy
    (repack UP when births filled the headroom; the shrink direction
    falls out for free at the next sweep boundary, whose segment start
    is an exact refactorization anyway) and resumes segment-wise from
    the first unprocessed row via ``_packed_scan``. The jit cache holds
    at most one entry per bucket — O(log K_max).

    ``seg_log`` (tests/benchmarks) receives one ``(bucket, start_row)``
    tuple per segment.
    """
    N, D = X.shape
    K_max = state.Z.shape[1]
    buckets = ibm.live_buckets(K_max)
    flavor = "pallas" if backend == "pallas" else "packed"
    kp = int(jnp.sum(state.active))
    B = ibm.pick_bucket(buckets, kp, PACK_HEADROOM)
    if seg_log is not None:
        seg_log.append((B, 0))
    done, raw, ovf_row = _packed_sweep_jit(
        state, X, hyp=hyp, backend=backend,
        refresh_every=refresh_every, B=B)
    ovf = int(ovf_row)
    if ovf < 0:
        return done
    # rare path: mid-sweep birth overflow — resume segment-wise
    Z, active, ZtZ, ZtX, m, ksweep, key, kalpha, ksx, ksa = raw
    alpha, sx, sa = state.alpha, state.sigma_x, state.sigma_a
    row = ovf
    kp = int(jnp.sum(active))
    while row < N:
        B = ibm.pick_bucket(buckets, kp, PACK_HEADROOM)
        if seg_log is not None:
            seg_log.append((B, row))
        Z, active, ZtZ, ZtX, m, _, _, ksweep, ovf_row = _packed_scan(
            Z, active, ZtZ, ZtX, m, X, ksweep, alpha, sx, sa, row,
            N=float(N), birth="gibbs", B=B, refresh_every=refresh_every,
            flip_flavor=flavor,
        )
        # ONE host round-trip per segment: the overflow row and the
        # next bucket choice's occupancy fetch together
        ovf, kp = map(int, jax.device_get((ovf_row, jnp.sum(active))))
        row = N if ovf < 0 else ovf
    return _finish_sweep_jit(state, X, hyp=hyp, Z=Z, active=active,
                             ZtZ=ZtZ, ZtX=ZtX, m=m, key=key,
                             kalpha=kalpha, ksx=ksx, ksa=ksa)


def collapsed_sweep(
    state: IBPState,
    X: Array,
    hyp: IBPHypers,
    backend: str = "ref",
    refresh_every: int = DEFAULT_REFRESH,
    k_live_buckets: str = "on",
    seg_log: list | None = None,
) -> IBPState:
    """One full collapsed Gibbs sweep over all rows + hyperparameter updates.

    ``k_live_buckets`` selects occupancy-adaptive packing for the
    fast/pallas backends (DESIGN.md §12): ``"on"`` (default) runs the
    unified packed core on the live K⁺ bucket via the host-dispatched
    packed scan; ``"off"`` runs the SAME core at the top bucket
    (B = K_max, G carry disabled) in one fully-jitted sweep — bitwise
    the pre-unification unpacked carry. The ref backend has no carry
    and ignores the knob.
    """
    if k_live_buckets not in K_LIVE_MODES:
        raise ValueError(
            f"k_live_buckets={k_live_buckets!r} not in {K_LIVE_MODES}"
        )
    if backend not in COLLAPSED_BACKENDS:
        raise ValueError(f"backend={backend!r} not in {COLLAPSED_BACKENDS}")
    if backend == "ref" or k_live_buckets == "off":
        return _collapsed_sweep_jit(state, X, hyp, backend=backend,
                                    refresh_every=refresh_every)
    return _collapsed_sweep_packed(state, X, hyp, backend, refresh_every,
                                   seg_log=seg_log)
