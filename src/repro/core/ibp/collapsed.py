"""Collapsed Gibbs sampler for the linear-Gaussian IBP (Griffiths & Ghahramani).

This is the serial baseline the paper compares against (Fig. 1). A is fully
integrated out. For each row n we use the posterior-predictive form

    x_n | z_n, Z_-n, X_-n ~ N( z_n H_-,  sigma_x^2 (1 + z_n M_- z_n^T) I )

with M_- = (Z_-^T Z_- + (sx^2/sa^2) I)^{-1}, H_- = M_- Z_-^T X_-, which makes
each bit flip O(K + D) after the per-row posterior map is in hand.
New dishes use the exact truncated-Gibbs step: row-n singletons are dropped
and j_new ~ P(j | rest) ∝ Poisson(j; alpha/N) · lik(j) over j = 0..J_MAX
(lik(j) closed-form: new columns only add j·sa^2 to the predictive variance).

Everything is padded to K_max with an ``active`` mask.

Two row-step backends (DESIGN.md §12), selected by ``backend=``:

* ``"ref"``  — fresh O(K^3 + K^2 D) Cholesky factorization per row (the
  original sampler; kept as the exact oracle the fast path is tested
  against). Per sweep: O(N (K^3 + K^2 D)).
* ``"fast"`` — the factorization is CARRIED across the row scan and moved
  between rows by rank-one Cholesky up/downdates + Sherman–Morrison:
  remove-row = one downdate, singleton drop / new dish = diagonal
  identity swaps (the affected row/col of W is exactly ratio·e_k), add-row
  = one update; H moves by the matching rank-one corrections. O(K^2 + K D)
  algorithmic work per row — though two rewrites deliberately trade big-O
  for BLAS constants: the up/downdate prefix sums go through a K^3 tril
  GEMM and the packed flip recomputes G = H Hᵀ (K^2 D) per row, both
  faster in wall-clock than their asymptotically-smaller forms at our K
  (DESIGN.md §12; carrying G rank-one would restore the strict bound).
  An exact refactorization every ``refresh_every`` rows plus a drift
  monitor (probe residual ‖M W p − p‖_∞ against the exactly maintained
  integer sufficient statistics, and the downdate's loss-of-positivity
  canary) force an early refresh when the carry degrades.
* ``"pallas"`` — the fast path with the K-sequential bit-flip recurrence
  executed by the ``kernels/collapsed_row`` Pallas kernel (VMEM-resident
  carry; compiled on TPU, interpret elsewhere).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels.collapsed_row import collapsed_row_flip

from . import math as ibm
from .state import IBPHypers, IBPState

Array = jax.Array

J_MAX = 4  # truncation for per-row new-dish draws (P(j>4 | alpha/N) is negligible)

COLLAPSED_BACKENDS = ("ref", "fast", "pallas")
DEFAULT_REFRESH = 64    # exact refactorization cadence of the fast path
DEFAULT_DRIFT_TOL = 1e-2  # probe-residual threshold forcing an early refresh
PROBE_EVERY = 4         # drift-probe cadence within the refresh window


def _log_poisson(j: Array, lam: Array) -> Array:
    return j * jnp.log(lam) - lam - jax.lax.lgamma(j + 1.0)


def _sample_dishes(kdish, q, mean, x_n, active_m, z, alpha, sx, sa, N, D,
                   birth):
    """Shared new-dish move: returns (z', active', newbits).

    ``birth`` selects the move:
      * "gibbs" — exact truncated Gibbs over j ∈ 0..J_MAX (G&G; collapsed
        baseline).
      * "mh" — the paper's Metropolis-Hastings move for the hybrid tail:
        propose j ~ Poisson(alpha/N) and accept with the marginal-likelihood
        ratio (prior ∝ proposal, so they cancel). Out-of-capacity proposals
        are rejected.
    """
    inv2s2 = 0.5 / (sx**2)
    lam = alpha / N
    s = 1.0 + q
    r = x_n - mean
    rss = jnp.dot(r, r)
    js = jnp.arange(J_MAX + 1, dtype=x_n.dtype)
    rho = (sa / sx) ** 2
    s_j = s + js * rho
    ll_j = -0.5 * D * jnp.log(s_j) - inv2s2 * rss / s_j
    free = 1.0 - jnp.maximum(active_m, z)
    n_free = jnp.sum(free)
    if birth == "gibbs":
        # exact truncated Gibbs: j ~ ∝ Poisson(j; lam) lik(j)
        logits = _log_poisson(js, lam) + ll_j
        logits = jnp.where(js <= n_free, logits, -jnp.inf)
        j_new = jax.random.categorical(kdish, logits).astype(x_n.dtype)
    else:
        # paper's MH: propose j ~ Poisson(lam), accept w.p. lik(j)/lik(0)
        kprop, kacc = jax.random.split(kdish)
        j_prop = jax.random.poisson(kprop, lam).astype(x_n.dtype)
        ok = (j_prop <= jnp.minimum(float(J_MAX), n_free))
        j_idx = jnp.clip(j_prop, 0, J_MAX).astype(jnp.int32)
        dll = ll_j[j_idx] - ll_j[0]
        acc = jnp.log(jax.random.uniform(kacc, (), dtype=x_n.dtype)) < dll
        j_new = jnp.where(ok & acc, j_prop, 0.0)
    # place new dishes in the first j_new free slots
    free_rank = jnp.cumsum(free) * free  # 1-indexed rank among free slots
    newbits = ((free_rank >= 1.0) & (free_rank <= j_new)).astype(z.dtype)
    z = z + newbits
    active_new = jnp.maximum(active_m, newbits)
    return z, active_new, newbits


def _row_step(carry, n, *, X, N, D, birth="gibbs"):
    """Resample row n's bits + new dishes, collapsed — the O(K^3) oracle.

    ``N`` is the GLOBAL number of observations — in the hybrid sampler the
    tail runs on processor p' with local rows but global-N priors
    ((m_k - Z_nk)/N and Poisson(alpha/N)), exactly as in the paper's
    pseudocode.
    """
    Z, active, ZtZ, ZtX, m, alpha, sx, sa, key = carry
    x_n = X[n]
    z = Z[n]
    # ---- remove row n from the sufficient statistics
    m_minus = m - z
    ZtZ_m = ZtZ - jnp.outer(z, z)
    ZtX_m = ZtX - jnp.outer(z, x_n)
    # drop row-n singletons (m_minus == 0 while z == 1): they are re-proposed
    # as part of the new-dish step (exact G&G scheme)
    singleton = active * (m_minus <= 0.5) * z
    z = z * (1.0 - singleton)
    active_m = active * (1.0 - (active * (m_minus <= 0.5)))  # live cols w/ support
    # ---- per-row factorization (exact; no carried state)
    ratio = (sx / sa) ** 2
    W = ibm.padded_W(ZtZ_m, active_m, ratio)
    M, _ = ibm.chol_inv_logdet(W)
    M = M * ibm.mask_outer(active_m)
    H = M @ (ZtX_m * active_m[:, None])  # (K, D) posterior mean map
    v = M @ z
    q = jnp.dot(z, v)
    mean = z @ H
    inv2s2 = 0.5 / (sx**2)

    K = Z.shape[1]
    key, kbits, kdish, kslot = jax.random.split(key, 4)
    uu = jnp.clip(jax.random.uniform(kbits, (K,), dtype=X.dtype), 1e-7, 1.0 - 1e-7)
    u = jnp.log(uu) - jnp.log1p(-uu)  # logit(U): accept z=1 iff logodds > u

    z, v, q, mean = collapsed_row_flip(
        M, H, x_n, z, v, q, mean, u, m_minus, active_m, N, inv2s2,
        flavor="jnp",
    )

    # ---- new dishes, j = 0..J_MAX
    z, active_new, _ = _sample_dishes(
        kdish, q, mean, x_n, active_m, z, alpha, sx, sa, N, D, birth
    )

    # ---- add row n back
    m_new = m_minus * active_m + z  # dead/singleton cols contribute 0
    ZtZ_n = ZtZ_m * ibm.mask_outer(active_m) + jnp.outer(z, z)
    ZtX_n = ZtX_m * active_m[:, None] + jnp.outer(z, x_n)
    Z = Z.at[n].set(z)
    return (Z, active_new, ZtZ_n, ZtX_n, m_new, alpha, sx, sa, key), None


class _FastCarry(NamedTuple):
    """Row-scan carry of the fast backend: sufficient statistics (exact,
    integer-valued where counts) + the carried factorization of the FULL
    row set (Lt = (chol W)^T, M = W^{-1} masked, H = M ZtX masked).
    L is carried transposed so the rank-one moves' cumulative sums run
    along contiguous rows (see math._chol_rank1_t)."""

    Z: Array
    active: Array
    ZtZ: Array
    ZtX: Array
    m: Array
    Lt: Array
    M: Array
    H: Array
    since: Array      # rows since last exact refactorization
    n_refresh: Array  # monitor/cadence-triggered refactorizations this scan
    key: Array


def _exact_factor(ZtZ, ZtX, active, ratio):
    """O(K^3 + K^2 D) exact (Lt, M, H) from the sufficient statistics."""
    W = ibm.padded_W(ZtZ, active, ratio)
    L, M = ibm.chol_inv(W)
    M = M * ibm.mask_outer(active)
    H = M @ (ZtX * active[:, None])
    return L.T, M, H


def _row_step_fast(carry: _FastCarry, n, *, X, N, D, birth, alpha, sx, sa,
                   refresh_every, drift_tol, flip_flavor):
    """Resample row n, collapsed, in O(K^2 + K D) via carried factorization.

    Transition algebra (DESIGN.md §12): with z = Z[n] and W carrying ALL
    rows, remove-row is the rank-one downdate W − z zᵀ, add-row the
    update W + z zᵀ; the matching Sherman–Morrison moves for M = W⁻¹ and
    H = M ZᵀX are
        remove:  M += (Mz)(Mz)ᵀ/δ,  H += (Mz)(zᵀH − x_nᵀ)/δ,  δ = 1 − zᵀMz
        add:     M −= (Mz)(Mz)ᵀ/δ,  H += (Mz)(x_nᵀ − zᵀH)/δ,  δ = 1 + zᵀMz
    Singleton drops and new-dish activations touch W only on the identity-
    vs-ratio diagonal of an exactly-decoupled coordinate (the dropped /
    appended column has no support in Z_-n, so its W row/col is exactly
    ratio·e_k), so L, M, H move by row/col masking + a diagonal write —
    no factorization work.

    Fixed-point shortcut: when the row leaves both its bits and the
    active set unchanged (the common case after burn-in), remove-row
    followed by add-row is the IDENTITY on (W, ZtX) — so the pre-removal
    (Lt, M, H) are carried through untouched instead of round-tripped
    through a downdate/update pair. This skips the L moves and the
    add-back Sherman–Morrison entirely AND accrues zero float drift on
    such rows; only rows that actually change pay the O(K^2) moves. The
    downdate canary still runs every row (it needs only p and an O(K)
    cumsum, not the L apply), as does the probe drift monitor.
    """
    Z, active, ZtZ, ZtX, m, Lt, M, H, since, n_refresh, key = carry
    x_n = X[n]
    z_old = Z[n]
    ratio = (sx / sa) ** 2
    # ---- remove row n from the sufficient statistics. The row-deleted
    # (ZtZ_m, ZtX_m) matrices are NEVER materialized on the hot path: the
    # probe needs one corrected matvec, the refresh branch (rare) builds
    # them locally, and the add-back fuses remove+add into one delta.
    m_minus = m - z_old
    # ---- remove row n from the posterior map (Sherman–Morrison)
    zu = z_old * active
    w = M @ zu
    # downdate canary WITHOUT applying the L move: p = L^{-1} z comes from
    # the carried inverse (L^T (M z), a matvec — no triangular solve) and
    # positive definiteness of W − z z^T is equivalent to all partial
    # d_j = 1 − cumsum(p^2)_j staying positive
    p_down = Lt @ w
    down_ok = jnp.all(1.0 - jnp.cumsum(p_down * p_down) > 1e-12)
    gamma = jnp.dot(zu, w)
    delta_s = jnp.maximum(1.0 - gamma, 1e-6)  # guard; probe catches real loss
    zH = zu @ H
    # scale the K-vector once, not the K^2/KD outers; the sqrt split keeps
    # M1 EXACTLY symmetric (the packed flip reads rows as columns)
    wr = w / jnp.sqrt(delta_s)
    wd = w / delta_s
    M1 = M + jnp.outer(wr, wr)
    H1 = H + jnp.outer(wd, zH - x_n)
    # ---- singleton drop: decoupled coordinates swap ratio -> identity.
    # M1/H1 already carry exact zeros on inactive rows/cols, so the mask
    # is a no-op unless a column actually dropped — gate it.
    drop = active * (m_minus <= 0.5)
    z = z_old * (1.0 - drop)
    active_m = active * (1.0 - drop)
    has_drop = jnp.any(drop > 0.5)

    def do_drop(ops):
        M1, H1 = ops
        keep2 = ibm.mask_outer(active_m)
        return M1 * keep2, H1 * active_m[:, None]

    M1, H1 = jax.lax.cond(has_drop, do_drop, lambda ops: ops, (M1, H1))
    # ---- drift monitor + periodic exact refactorization
    # probe p = active_m against the EXACT integer stats: W_m p collapses to
    # one matvec (masking + ratio on the diagonal fold into active_m; the
    # row removal is the O(K) correction -z_old (z_old . p)).
    # Probed every PROBE_EVERY rows (deterministic): detection is delayed by
    # at most PROBE_EVERY - 1 rows, the refresh_every bound is unaffected,
    # and the downdate canary still runs every row.
    def do_probe(_):
        tm = ZtZ @ active_m - z_old * jnp.dot(z_old, active_m)
        probe_t = active_m * tm + ratio * active_m
        return jnp.max(jnp.abs(M1 @ probe_t - active_m))

    drift = jax.lax.cond(
        since % PROBE_EVERY == 0, do_probe, lambda _: jnp.zeros((), X.dtype),
        None,
    )
    # NaN-safe: ~(drift <= tol) is True for NaN, (drift > tol) is not
    need = (since >= refresh_every - 1) | (~down_ok) | (~(drift <= drift_tol))

    def do_refresh(_):
        ZtZ_m = ZtZ - jnp.outer(z_old, z_old)
        ZtX_m = ZtX - jnp.outer(z_old, x_n)
        L2, M2 = ibm.chol_inv(ibm.padded_W(ZtZ_m, active_m, ratio))
        M2 = M2 * ibm.mask_outer(active_m)
        return L2.T, M2, M2 @ (ZtX_m * active_m[:, None])

    # Lt_rm is the ROW-REMOVED factor (only materialized on refresh; on the
    # cheap path the L downdate is deferred into the `changed` branch below)
    Lt_rm, M1, H1 = jax.lax.cond(
        need, do_refresh, lambda _: (Lt, M1, H1), None
    )
    since = jnp.where(need, 0, since + 1)
    n_refresh = n_refresh + need.astype(n_refresh.dtype)

    # ---- bit flips (identical recurrence + PRNG stream as the oracle)
    inv2s2 = 0.5 / (sx**2)
    K = Z.shape[1]
    key, kbits, kdish, kslot = jax.random.split(key, 4)
    uu = jnp.clip(jax.random.uniform(kbits, (K,), dtype=X.dtype), 1e-7, 1.0 - 1e-7)
    u = jnp.log(uu) - jnp.log1p(-uu)

    # (v, q, mean) of the row-removed state. On the clean path (no drop, no
    # refresh) they fall out of the Sherman–Morrison vectors already in
    # hand: v = M1 z = w/δ, q = γ/δ, mean = z H1 = zH + (γ/δ)(zH − x) —
    # zero extra matvecs. Any drop/refresh invalidates those identities.
    def vqm_closed(_):
        gd = gamma / delta_s
        return wd, gd, zH + gd * (zH - x_n)

    def vqm_matvec(_):
        v = M1 @ z
        return v, jnp.dot(z, v), z @ H1

    v, q, mean = jax.lax.cond(
        has_drop | need, vqm_matvec, vqm_closed, None
    )
    z, v, q, mean = collapsed_row_flip(
        M1, H1, x_n, z, v, q, mean, u, m_minus, active_m, N, inv2s2,
        flavor=flip_flavor,
    )

    # ---- new dishes
    z, active_new, newbits = _sample_dishes(
        kdish, q, mean, x_n, active_m, z, alpha, sx, sa, N, D, birth
    )

    # ---- add row n back. Stats move only when something moved: unchanged
    # rows carry (ZtZ, ZtX) through untouched (remove+add is the identity);
    # changed rows fuse remove+add into one delta; a drop (rare) takes the
    # masked two-step so the dropped column's row/col is zeroed exactly.
    m_new = m_minus * active_m + z
    changed = (
        need | jnp.any(z != z_old) | jnp.any(active_new != active)
    )

    def stats_moved(_):
        def masked(_):
            return ((ZtZ - jnp.outer(z_old, z_old))
                    * ibm.mask_outer(active_m) + jnp.outer(z, z),
                    (ZtX - jnp.outer(z_old, x_n)) * active_m[:, None]
                    + jnp.outer(z, x_n))

        def fused(_):
            return (ZtZ + jnp.outer(z, z) - jnp.outer(z_old, z_old),
                    ZtX + jnp.outer(z - z_old, x_n))

        return jax.lax.cond(has_drop, masked, fused, None)

    ZtZ_n, ZtX_n = jax.lax.cond(
        changed | has_drop, stats_moved, lambda _: (ZtZ, ZtX), None
    )

    def apply_moves(_):
        # the factor really moved: finish remove -> drop -> activate -> add
        Lt1 = jax.lax.cond(
            need,
            lambda __: Lt_rm,  # refresh already produced the removed factor
            lambda __: ibm.chol_rank1_downdate_t(Lt, p_down)[0],
            None,
        )

        # drop/activation diagonal swaps are exact no-ops unless a column
        # actually dropped or was born this row — gate the K^2 mask work
        def diag_swaps(ops):
            Lt1, M1, H1 = ops
            keep2 = ibm.mask_outer(active_m)
            Lt1 = Lt1 * keep2 + jnp.diag(1.0 - active_m)
            # activation: decoupled coordinates swap identity -> ratio
            Lt1 = Lt1 + jnp.diag(newbits * (jnp.sqrt(ratio) - 1.0))
            M1b = M1 + jnp.diag(newbits / ratio)
            H1b = H1 * (1.0 - newbits)[:, None]
            return Lt1, M1b, H1b

        Lt1, M1b, H1b = jax.lax.cond(
            has_drop | jnp.any(newbits > 0.5), diag_swaps, lambda ops: ops,
            (Lt1, M1, H1),
        )
        w2 = M1b @ z
        Lt2 = ibm.chol_rank1_update_t(Lt1, Lt1 @ w2)
        d2 = 1.0 + jnp.dot(z, w2)
        w2r = w2 / jnp.sqrt(d2)
        M2 = M1b - jnp.outer(w2r, w2r)
        H2 = H1b + jnp.outer(w2 / d2, x_n - z @ H1b)
        return Lt2, M2, H2

    Lt_n, M_n, H_n = jax.lax.cond(
        changed, apply_moves, lambda _: (Lt, M, H), None
    )
    Z = Z.at[n].set(z)
    return _FastCarry(
        Z=Z, active=active_new, ZtZ=ZtZ_n, ZtX=ZtX_n, m=m_new,
        Lt=Lt_n, M=M_n, H=H_n, since=since, n_refresh=n_refresh, key=key,
    ), None


def collapsed_row_scan(
    Z: Array,
    active: Array,
    ZtZ: Array,
    ZtX: Array,
    m: Array,
    X: Array,
    key: Array,
    alpha: Array,
    sx: Array,
    sa: Array,
    *,
    N: float,
    birth: str = "gibbs",
    backend: str = "ref",
    refresh_every: int = DEFAULT_REFRESH,
    drift_tol: float = DEFAULT_DRIFT_TOL,
) -> tuple[Array, Array, Array, Array, Array, Array]:
    """Scan the collapsed row step over every row of ``X``.

    The shared entry point of the serial baseline (``collapsed_sweep``)
    and the hybrid tail (``hybrid._tail_sub_iteration``). Returns
    (Z, active, ZtZ, ZtX, m, n_refresh); ``n_refresh`` counts exact
    refactorizations (cadence + monitor) and is 0 on the ref backend.
    """
    if backend not in COLLAPSED_BACKENDS:
        raise ValueError(f"backend={backend!r} not in {COLLAPSED_BACKENDS}")
    n_rows, D = X.shape
    rows = jnp.arange(n_rows)
    if backend == "ref":
        body = partial(_row_step, X=X, N=N, D=D, birth=birth)
        carry = (Z, active, ZtZ, ZtX, m, alpha, sx, sa, key)
        carry, _ = jax.lax.scan(body, carry, rows)
        Z, active, ZtZ, ZtX, m = carry[:5]
        return Z, active, ZtZ, ZtX, m, jnp.zeros((), jnp.int32)
    ratio = (sx / sa) ** 2
    Lt, M, H = _exact_factor(ZtZ, ZtX, active, ratio)
    body = partial(
        _row_step_fast, X=X, N=N, D=D, birth=birth,
        alpha=alpha, sx=sx, sa=sa,
        refresh_every=refresh_every, drift_tol=drift_tol,
        flip_flavor="pallas" if backend == "pallas" else "packed",
    )
    carry = _FastCarry(
        Z=Z, active=active, ZtZ=ZtZ, ZtX=ZtX, m=m, Lt=Lt, M=M, H=H,
        since=jnp.zeros((), jnp.int32), n_refresh=jnp.zeros((), jnp.int32),
        key=key,
    )
    carry, _ = jax.lax.scan(body, carry, rows)
    return carry.Z, carry.active, carry.ZtZ, carry.ZtX, carry.m, carry.n_refresh


@partial(jax.jit, static_argnames=("hyp", "backend", "refresh_every"))
def collapsed_sweep(
    state: IBPState,
    X: Array,
    hyp: IBPHypers,
    backend: str = "ref",
    refresh_every: int = DEFAULT_REFRESH,
) -> IBPState:
    """One full collapsed Gibbs sweep over all rows + hyperparameter updates."""
    N, D = X.shape
    Z, active = state.Z, state.active
    m = jnp.sum(Z * active[None, :], axis=0)
    ZtZ = (Z.T @ Z) * ibm.mask_outer(active)
    ZtX = (Z.T @ X) * active[:, None]
    key, ksweep, kalpha, ksx, ksa = jax.random.split(state.key, 5)

    Z, active, ZtZ, ZtX, m, _ = collapsed_row_scan(
        Z, active, ZtZ, ZtX, m, X, ksweep,
        state.alpha, state.sigma_x, state.sigma_a,
        N=float(N), birth="gibbs", backend=backend,
        refresh_every=refresh_every,
    )
    alpha, sx, sa = state.alpha, state.sigma_x, state.sigma_a

    # prune columns that died during the sweep
    active = active * (m > 0.5)
    mask2 = ibm.mask_outer(active)
    ZtZ = ZtZ * mask2
    ZtX = ZtX * active[:, None]
    Z = Z * active[None, :]
    m = m * active
    k_plus = jnp.sum(active)

    # alpha | K+ ~ Gamma(a + K+, b + H_N)
    if hyp.resample_alpha:
        HN = ibm.harmonic(N)
        alpha = ibm.gamma_draw(kalpha, hyp.a_alpha + k_plus, hyp.b_alpha + HN)

    # sigma_x, sigma_a via random-walk MH on log-scale against collapsed lik
    if hyp.resample_sigmas:
        trXtX = jnp.sum(X * X)

        def cll(sx_, sa_):
            return ibm.collapsed_loglik(
                trXtX, ZtX, ZtZ, active, jnp.float32(N), D, sx_, sa_
            )

        def mh(key_, cur, other, which):
            kprop, kacc = jax.random.split(key_)
            prop = cur * jnp.exp(0.1 * jax.random.normal(kprop, (), dtype=cur.dtype))
            if which == "x":
                d = cll(prop, other) - cll(cur, other)
            else:
                d = cll(other, prop) - cll(other, cur)
            # log-normal RW: include log-scale Jacobian (log prop - log cur)
            d = d + jnp.log(prop) - jnp.log(cur)
            acc = jnp.log(jax.random.uniform(kacc, (), dtype=cur.dtype)) < d
            return jnp.where(acc, prop, cur)

        sx = mh(ksx, sx, sa, "x")
        sa = mh(ksa, sa, sx, "a")

    return IBPState(
        Z=Z, A=state.A, pi=state.pi, active=active, tail=state.tail,
        alpha=alpha, sigma_x=sx, sigma_a=sa, key=key,
        p_prime=state.p_prime, it=state.it + 1,
    )
