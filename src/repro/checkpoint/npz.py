"""Atomic npz checkpointing with keep-k retention and auto-resume.

Layout: <dir>/step_<n>.npz written as .tmp then os.replace (atomic on POSIX),
so a crash mid-write never corrupts the latest checkpoint — the restart path
(runtime/driver.py) always finds either the previous or the new complete file.

Pytrees are flattened to dict[str_path] = leaf; structure round-trips through
jax.tree flatten/unflatten against a template pytree with identical structure.
"""
from __future__ import annotations

import os
import re
from typing import Any

import jax
import numpy as np

Array = jax.Array


def _is_key(x) -> bool:
    return hasattr(x, "dtype") and jax.dtypes.issubdtype(x.dtype, jax.dtypes.prng_key)


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    leaves, treedef = jax.tree.flatten(tree)
    out = {}
    for i, x in enumerate(leaves):
        if _is_key(x):
            x = jax.random.key_data(x)
        out[f"leaf_{i:05d}"] = np.asarray(x)
    return out


def save_pytree(path: str, tree: Any, step: int, keep: int = 3) -> str:
    os.makedirs(path, exist_ok=True)
    fname = os.path.join(path, f"step_{step:09d}.npz")
    tmp = fname + ".tmp"
    with open(tmp, "wb") as fh:  # file handle avoids numpy's suffix appending
        np.savez(fh, **_flatten(tree))
    os.replace(tmp, fname)
    # retention
    steps = sorted(all_steps(path))
    for s in steps[:-keep]:
        try:
            os.remove(os.path.join(path, f"step_{s:09d}.npz"))
        except OSError:
            pass
    return fname


def save_arrays(path: str, arrays: dict[str, np.ndarray]) -> str:
    """Atomic SELF-DESCRIBING npz: named arrays, loadable with no
    template pytree. The persistence layer of artifacts that must be
    restorable independently of sampler state — the posterior
    ``SampleBank`` in particular (DESIGN.md §15). Same tmp + os.replace
    crash-safety as ``save_pytree``."""
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "wb") as fh:
        np.savez(fh, **{k: np.asarray(v) for k, v in arrays.items()})
    os.replace(tmp, path)
    return path


def load_arrays(path: str) -> dict[str, np.ndarray]:
    """Load a ``save_arrays`` npz back into a name -> array dict."""
    with np.load(path) as data:
        return {k: data[k] for k in data.files}


def update_json(path: str, update) -> str:
    """Tolerant read-modify-write of a small JSON artifact (the durable
    BENCH_<date>.json perf trajectory, which has two writers:
    ``benchmarks/run.py`` and ``repro.launch.serve_ibp``). A corrupt or
    half-written file reads as {} instead of crashing the caller, and
    the write is tmp + os.replace — the same crash contract as the npz
    checkpoints. ``update`` maps the current dict to the new one."""
    import json

    data: dict = {}
    if os.path.exists(path):
        try:
            with open(path) as fh:
                data = json.load(fh)
        except (OSError, json.JSONDecodeError):
            data = {}
    data = update(data)
    tmp = path + ".tmp"
    with open(tmp, "w") as fh:
        json.dump(data, fh, indent=1)
    os.replace(tmp, path)
    return path


def all_steps(path: str) -> list[int]:
    if not os.path.isdir(path):
        return []
    out = []
    for f in os.listdir(path):
        m = re.fullmatch(r"step_(\d+)\.npz", f)
        if m:
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(path: str) -> int | None:
    steps = all_steps(path)
    return steps[-1] if steps else None


def load_pytree(path: str, template: Any, step: int) -> Any:
    fname = os.path.join(path, f"step_{step:09d}.npz")
    with np.load(fname) as data:
        leaves = [data[f"leaf_{i:05d}"] for i in range(len(data.files))]
    _, treedef = jax.tree.flatten(template)
    t_leaves = jax.tree.leaves(template)
    if len(leaves) != len(t_leaves):
        raise ValueError(
            f"checkpoint {fname} has {len(leaves)} leaves but the template "
            f"has {len(t_leaves)} — the checkpoint predates a state-layout "
            f"change; clear or rename the checkpoint directory to start fresh"
        )
    cast = []
    for l, t in zip(leaves, t_leaves):
        if _is_key(t):
            cast.append(jax.random.wrap_key_data(jax.numpy.asarray(l)))
        elif hasattr(t, "dtype"):
            if l.dtype.kind == "V":  # npz loads ml_dtypes (bf16 etc.) as void
                l = l.view(np.dtype(t.dtype))
            cast.append(jax.numpy.asarray(l, t.dtype))
        else:
            cast.append(l)
    return jax.tree.unflatten(treedef, cast)


def restore(path: str, template: Any) -> tuple[Any, int] | None:
    """Load the newest complete checkpoint, or None if none exists."""
    step = latest_step(path)
    if step is None:
        return None
    return load_pytree(path, template, step), step
