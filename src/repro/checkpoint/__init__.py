from .npz import latest_step, load_pytree, restore, save_pytree

__all__ = ["save_pytree", "load_pytree", "restore", "latest_step"]
