from .npz import (
    latest_step,
    load_arrays,
    load_pytree,
    restore,
    save_arrays,
    save_pytree,
    update_json,
)

__all__ = [
    "save_pytree",
    "load_pytree",
    "restore",
    "latest_step",
    "save_arrays",
    "load_arrays",
    "update_json",
]
