"""RG-LRU recurrent block (RecurrentGemma / Griffin), TPU-adapted.

Recurrence (Griffin eq. 1-4):
    r_t = sigmoid(W_a x_t + b_a)          recurrence gate
    i_t = sigmoid(W_x x_t + b_x)          input gate
    log a_t = -c * softplus(Lambda) * r_t             (c = 8)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Same chunked-scan strategy as ssm.py but the state is only (B, d_rnn) — the
per-chunk materialization is (B, Lc, d_rnn), tiny; hence the hybrid arch also
runs ``long_500k``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .modules import FSDP, TP, linear_init, maybe_shard

Array = jax.Array
_C = 8.0


class RGLRUCache(NamedTuple):
    conv: Array    # (B, conv_k - 1, d_rnn)
    h: Array       # (B, d_rnn) f32
    length: Array


def rglru_init(key, cfg, *, stack: int | None = None):
    d = cfg.d_model
    dr = cfg.d_rnn or d
    ks = jax.random.split(key, 7)
    params, specs = {}, {}
    params["in_x"], specs["in_x"] = linear_init(ks[0], d, dr, stack=stack)
    params["in_gate"], specs["in_gate"] = linear_init(ks[1], d, dr, stack=stack)
    conv_shape = (cfg.ssm_conv, dr) if stack is None else (stack, cfg.ssm_conv, dr)
    params["conv_w"] = 0.1 * jax.random.normal(ks[2], conv_shape, jnp.float32)
    specs["conv_w"] = P(*((None,) * (len(conv_shape) - 1) + (TP,)))
    params["w_a"], specs["w_a"] = linear_init(ks[3], dr, dr, stack=stack,
                                              pspec=(None, TP))
    params["w_i"], specs["w_i"] = linear_init(ks[4], dr, dr, stack=stack,
                                              pspec=(None, TP))
    lam_shape = (dr,) if stack is None else (stack, dr)
    params["lam"] = jnp.full(lam_shape, 0.65)  # a ~ 0.9^c after softplus
    specs["lam"] = P(*((None,) * (len(lam_shape) - 1) + (TP,)))
    params["out"], specs["out"] = linear_init(ks[5], dr, d, stack=stack,
                                              pspec=(TP, FSDP))
    return params, specs


def _lru_scan_chunked(a: Array, bx: Array, h0: Array, chunk: int):
    """h_t = a_t * h_{t-1} + bx_t; a, bx (B, S, dr)."""
    B, S, dr = a.shape
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        bx = jnp.pad(bx, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    n_chunks = Sp // chunk
    a_c = a.reshape(B, n_chunks, chunk, dr).transpose(1, 0, 2, 3)
    bx_c = bx.reshape(B, n_chunks, chunk, dr).transpose(1, 0, 2, 3)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    def body(h, ab):
        a_j, bx_j = ab
        aa, bb = jax.lax.associative_scan(combine, (a_j, bx_j), axis=1)
        hs = aa * h[:, None] + bb
        return hs[:, -1], hs

    h_last, hs = jax.lax.scan(body, h0, (a_c, bx_c))
    return hs.transpose(1, 0, 2, 3).reshape(B, Sp, dr)[:, :S], h_last


def rglru_apply(
    p: dict,
    x: Array,
    cfg,
    *,
    mode: str,
    cache: RGLRUCache | None = None,
    act_spec=P(),
) -> tuple[Array, RGLRUCache | None]:
    from .ssm import _causal_conv

    B, S, d = x.shape
    dr = cfg.d_rnn or d

    gate = jax.nn.gelu(
        maybe_shard(
            jnp.einsum("bsd,df->bsf", x, p["in_gate"]), act_spec
        )
    )
    xr = maybe_shard(
        jnp.einsum("bsd,df->bsf", x, p["in_x"]), act_spec
    )
    history = cache.conv if mode == "decode" and cache is not None else None
    xc = _causal_conv(xr, p["conv_w"], history)

    r = jax.nn.sigmoid(jnp.einsum("bsf,fg->bsg", xc, p["w_a"]).astype(jnp.float32))
    i = jax.nn.sigmoid(jnp.einsum("bsf,fg->bsg", xc, p["w_i"]).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"].astype(jnp.float32))[None, None] * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (
        i * xc.astype(jnp.float32)
    )

    if mode == "decode":
        assert cache is not None and S == 1
        h = a[:, 0] * cache.h + gated[:, 0]
        hs = h[:, None]
        new_conv = jnp.concatenate([cache.conv, xr], axis=1)[:, 1:]
        new_cache = RGLRUCache(new_conv, h, cache.length + 1)
    else:
        h0 = jnp.zeros((B, dr), jnp.float32)
        hs, _ = _lru_scan_chunked(a, gated, h0, cfg.scan_chunk)
        new_cache = None

    y = hs.astype(x.dtype) * gate
    out = maybe_shard(
        jnp.einsum("bsf,fd->bsd", y, p["out"]), act_spec
    )
    return out, new_cache


def init_rglru_cache(cfg, B: int, dtype):
    dr = cfg.d_rnn or cfg.d_model
    return RGLRUCache(
        conv=jnp.zeros((B, cfg.ssm_conv - 1, dr), dtype),
        h=jnp.zeros((B, dr), jnp.float32),
        length=jnp.zeros((), jnp.int32),
    )
