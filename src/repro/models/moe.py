"""Mixture-of-Experts FFN: top-k routing with two dispatch schedules.

``cfg.moe_impl`` selects the dispatch (both produce the same math, modulo
which over-capacity tokens drop):

* ``"gather"`` — global capacity table: scatter token indices into an (E, C)
  table, gather expert inputs from the full token buffer, batched expert
  einsum, scatter-add back. Simple and single-device friendly, but under
  SPMD the (T, d) token buffer is data-sharded while the table is
  expert-sharded, so XLA must ALL-GATHER the whole token buffer per layer
  (measured: 2 x 20 GiB/layer/device for deepseek-v2 train_4k, plus the
  scatter-add transpose all-reduces — the dominant collective cost of the
  baseline; see EXPERIMENTS.md §Perf).

* ``"a2a"`` — the TPU-native schedule (shard_map): tokens stay sharded over
  (dp, tp); each device builds LOCAL (E, C_dev) dispatch tables from its own
  T_dev tokens, ALL-TO-ALLs the (E, C_dev, d) slabs over the model axis so
  each expert owner receives (E_loc, C_dev * tp, d), runs its local expert
  GEMMs, and reverses the all-to-all. Per-token traffic is O(k * d) instead
  of O(T_global * d): ~20x fewer collective bytes at deepseek-v2 scale.
  Capacity is per-device (GShard group semantics).

TPU adaptation (both paths): no per-token sort network — position-in-expert
comes from a cumsum over the one-hot assignment; expert GEMMs are batched
einsums over a dense (E, C, d) layout so the MXU sees aligned matmuls.

DeepSeek-V2 details: ``n_shared_experts`` always-on experts are fused as one
dense SwiGLU of width shared*d_ff_expert; routed gates are softmax-then-topk,
renormalized.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat

from .modules import FSDP, TP, linear_init, maybe_shard

Array = jax.Array


def moe_init(key, cfg, *, stack: int | None = None):
    d = cfg.d_model
    E = cfg.n_experts
    ff = cfg.d_ff_expert or cfg.d_ff
    ks = jax.random.split(key, 4)
    params, specs = {}, {}
    params["router"], specs["router"] = linear_init(
        ks[0], d, E, stack=stack, pspec=(FSDP, None)
    )
    # experts: fused gate+up (E, d, 2ff), down (E, ff, d); E shards over TP
    shape_i = (E, d, 2 * ff) if stack is None else (stack, E, d, 2 * ff)
    shape_o = (E, ff, d) if stack is None else (stack, E, ff, d)
    pre = (None,) * (0 if stack is None else 1)
    params["wi"] = 0.02 * jax.random.normal(ks[1], shape_i, jnp.float32)
    specs["wi"] = P(*(pre + (TP, FSDP, None)))
    params["wo"] = 0.02 * jax.random.normal(ks[2], shape_o, jnp.float32)
    specs["wo"] = P(*(pre + (TP, None, FSDP)))
    if cfg.n_shared_experts:
        sh_ff = cfg.n_shared_experts * ff
        params["shared_wi"], specs["shared_wi"] = linear_init(
            ks[3], d, 2 * sh_ff, stack=stack
        )
        params["shared_wo"], specs["shared_wo"] = linear_init(
            jax.random.fold_in(ks[3], 1), sh_ff, d, stack=stack, pspec=(TP, FSDP)
        )
    return params, specs


def _swiglu(x: Array) -> Array:
    g, u = jnp.split(x, 2, axis=-1)
    return jax.nn.silu(g) * u


def _route(xt: Array, router: Array, E: int, k: int):
    """Router: probs, top-k gates/ids, and the load-balance aux ingredients.

    Returns (gate_vals (T,k) f32, expert_ids (T,k) i32,
             counts (E,) f32, prob_sum (E,) f32).
    """
    T = xt.shape[0]
    logits = jnp.einsum("td,de->te", xt, router.astype(xt.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)          # (T, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
    eid = expert_ids.reshape(T * k)
    counts = jnp.zeros((E,), jnp.float32).at[eid].add(1.0)
    return gate_vals, expert_ids, counts, jnp.sum(probs, axis=0)


def _dispatch_tables(expert_ids: Array, gate_vals: Array, counts: Array,
                     E: int, C: int, T: int):
    """Sort-based dispatch (no O(T*k*E) one-hot): (E, C) token-index table
    (dropped/unfilled slots -> T, a zero row) and the matching gate table."""
    k = expert_ids.shape[1]
    eid = expert_ids.reshape(T * k)
    order = jnp.argsort(eid, stable=True)                    # (T*k,)
    sorted_eid = eid[order]
    starts = jnp.cumsum(counts) - counts                     # (E,)
    rank = (jnp.arange(T * k, dtype=jnp.int32)
            - starts[sorted_eid].astype(jnp.int32))
    keep = rank < C
    tok_all = jnp.tile(
        jnp.arange(T, dtype=jnp.int32)[:, None], (1, k)
    ).reshape(-1)
    s_tok = tok_all[order]
    s_gate = gate_vals.reshape(-1)[order]
    table = jnp.full((E, C), T, jnp.int32)
    table = table.at[sorted_eid, rank].set(
        jnp.where(keep, s_tok, T), mode="drop"
    )
    gtable = jnp.zeros((E, C), jnp.float32)
    gtable = gtable.at[sorted_eid, rank].set(
        jnp.where(keep, s_gate, 0.0), mode="drop"
    )
    return table, gtable


def _expert_ffn(xe: Array, wi: Array, wo: Array) -> Array:
    """Batched expert GEMMs: (E, C, d) -> (E, C, d)."""
    h = _swiglu(jnp.einsum("ecd,edf->ecf", xe, wi.astype(xe.dtype)))
    return jnp.einsum("ecf,efd->ecd", h, wo.astype(xe.dtype))


# ---------------------------------------------------------------------------
# dispatch schedule 1: global-capacity gather (baseline)
# ---------------------------------------------------------------------------


def _moe_gather(p: dict, xt: Array, cfg, act_spec) -> tuple[Array, Array]:
    T, d = xt.shape
    E, k = cfg.n_experts, cfg.top_k
    gate_vals, expert_ids, counts, prob_sum = _route(xt, p["router"], E, k)
    aux = E * jnp.sum((counts / T) * (prob_sum / T))
    C = max(1, int(T * k / E * cfg.capacity_factor))
    table, gtable = _dispatch_tables(expert_ids, gate_vals, counts, E, C, T)

    xpad = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)], axis=0)
    xe = xpad[table]                                          # (E, C, d)
    xe = maybe_shard(xe, act_spec)
    ye = _expert_ffn(xe, p["wi"], p["wo"])
    ye = ye * gtable[..., None].astype(ye.dtype)
    y = jnp.zeros((T + 1, d), ye.dtype).at[table.reshape(-1)].add(
        ye.reshape(E * C, d)
    )[:T]
    return y, aux


# ---------------------------------------------------------------------------
# dispatch schedule 2: all-to-all over the model axis (optimized)
# ---------------------------------------------------------------------------


def _a2a_applicable(cfg, specs, S: int) -> bool:
    if cfg.moe_impl != "a2a" or specs.mesh is None or specs.tp is None:
        return False
    tp_n = int(specs.mesh.shape[specs.tp])
    # sequence must shard over tp (train/prefill); decode (S=1) keeps the
    # gather path, whose global capacity drops fewer tokens at tiny T
    return cfg.n_experts % tp_n == 0 and tp_n > 1 and S % tp_n == 0


def _moe_a2a(p: dict, x: Array, cfg, specs) -> tuple[Array, Array]:
    """shard_map MoE: local dispatch -> a2a -> expert GEMM -> a2a -> combine.

    x: (B, S, d) global; tokens shard over (dp on batch, tp on sequence).
    Capacity is per-device (GShard group semantics).
    """
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    mesh, dp, tp = specs.mesh, specs.dp, specs.tp
    dp_axes = dp if isinstance(dp, tuple) else ((dp,) if dp else ())
    dp_n = int(np.prod([mesh.shape[a] for a in dp_axes])) if dp_axes else 1
    b_ok = dp_axes and B % dp_n == 0
    bdim = (dp if b_ok else None)
    x_spec = P(bdim, tp, None)
    T_global = B * S
    # axes over which tokens are actually partitioned (for exact aux stats)
    stat_axes = (tuple(dp_axes) if b_ok else ()) + (tp,)

    def local_fn(x_loc, router, wi, wo):
        # x_loc: (B_loc, S_loc, d); wi/wo: (E_loc, ...) expert slabs
        Bl, Sl, _ = x_loc.shape
        T = Bl * Sl
        xt = x_loc.reshape(T, d)
        gate_vals, expert_ids, counts, prob_sum = _route(xt, router, E, k)
        # load-balance aux from GLOBAL stats (one tiny (E,) psum — exact)
        g_counts = jax.lax.psum(counts, stat_axes)
        g_prob = jax.lax.psum(prob_sum, stat_axes)
        aux = E * jnp.sum((g_counts / T_global) * (g_prob / T_global))

        C = max(1, int(T * k / E * cfg.capacity_factor))
        table, gtable = _dispatch_tables(expert_ids, gate_vals, counts,
                                         E, C, T)
        xpad = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)], axis=0)
        xe = xpad[table]                                      # (E, C, d)
        # exchange: every device sends expert-block j to model-rank j
        xe = jax.lax.all_to_all(xe, tp, split_axis=0, concat_axis=1,
                                tiled=True)                   # (E_loc, C*tp, d)
        ye = _expert_ffn(xe, wi, wo)
        ye = jax.lax.all_to_all(ye, tp, split_axis=1, concat_axis=0,
                                tiled=True)                   # (E, C, d)
        ye = ye * gtable[..., None].astype(ye.dtype)
        y = jnp.zeros((T + 1, d), ye.dtype).at[table.reshape(-1)].add(
            ye.reshape(E * C, d)
        )[:T]
        return y.reshape(Bl, Sl, d), aux

    y, aux = compat.shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(x_spec, P(), P(tp, None, None), P(tp, None, None)),
        out_specs=(x_spec, P()),
        check_vma=False,
    )(x, p["router"], p["wi"], p["wo"])
    return y, aux


# ---------------------------------------------------------------------------
# public entry
# ---------------------------------------------------------------------------


def moe_apply(p: dict, x: Array, cfg, *, specs=None,
              act_spec=None) -> tuple[Array, Array]:
    """Returns (y, aux_loss). x: (B, S, d)."""
    from .transformer import ActSpecs  # local import (cycle)

    if specs is None:
        specs = ActSpecs() if act_spec is None else ActSpecs(exp=act_spec)
    B, S, d = x.shape

    if _a2a_applicable(cfg, specs, S):
        y, aux = _moe_a2a(p, x, cfg, specs)                   # (B, S, d)
    else:
        y, aux = _moe_gather(p, x.reshape(B * S, d), cfg, specs.exp)
        y = y.reshape(B, S, d)

    if cfg.n_shared_experts:
        # same tp/dp schedule choice as the dense MLP (§Perf iters 2-3)
        sh_spec = specs.hid if specs.mlp_dp else specs.feat
        sh = _swiglu(jnp.einsum("bsd,df->bsf", x,
                                p["shared_wi"].astype(x.dtype)))
        sh = maybe_shard(sh, sh_spec)
        y = y + maybe_shard(
            jnp.einsum("bsf,fd->bsd", sh, p["shared_wo"].astype(x.dtype)),
            specs.hid,
        )

    return y, aux
