"""Training / serving steps built on transformer.model_apply.

``make_train_step`` returns a pure (params, opt_state, batch, rng) ->
(params, opt_state, metrics) function suitable for pjit; ``make_prefill_step``
and ``make_decode_step`` are the serving counterparts. All are shape-
polymorphic over batch/seq and close over (cfg, specs, optimizer).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .transformer import ActSpecs, init_caches, model_apply, pad_vocab

Array = jax.Array


def cross_entropy(logits: Array, labels: Array, vocab: int) -> tuple[Array, Array]:
    """Masked CE over real (unpadded) vocab; labels < 0 are ignored.

    Returns (loss, n_tokens). logits f32 (B, S, Vp).
    """
    Vp = logits.shape[-1]
    mask = (labels >= 0) & (labels < vocab)
    safe = jnp.where(mask, labels, 0)
    # mask padded vocab slots
    pad_bias = jnp.where(
        jnp.arange(Vp) < vocab, 0.0, -1e30
    ).astype(logits.dtype)
    logits = logits + pad_bias[None, None, :]
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    return jnp.sum(nll), jnp.sum(mask)


def cast_params(params, cfg):
    """Mixed precision: f32 master weights, bf16 compute copy (cast fuses
    before the FSDP all-gather, so gathers move bf16 bytes)."""
    if cfg.dtype != "bfloat16":
        return params
    return jax.tree.map(
        lambda p: p.astype(jnp.bfloat16) if p.dtype == jnp.float32 else p, params
    )


def lm_loss(params, batch, cfg, specs: ActSpecs, aux_weight: float = 0.01):
    params = cast_params(params, cfg)
    tokens = batch["tokens"]
    if "labels" in batch:  # pipeline pre-shifted: model sees all S positions
        inputs, labels = batch, batch["labels"]
    else:
        inputs = {**batch, "tokens": tokens[:, :-1]}
        labels = tokens[:, 1:]
    logits, aux, _ = model_apply(params, inputs, cfg, mode="train", specs=specs)
    nll, n = cross_entropy(logits, labels, cfg.vocab)
    loss = nll / jnp.maximum(n, 1.0) + aux_weight * aux
    return loss, {"nll": nll, "tokens": n, "aux": aux}


def make_train_step(cfg, optimizer, specs: ActSpecs = ActSpecs(),
                    aux_weight: float = 0.01):
    """One optimizer step. ``cfg.micro_batches > 1`` splits the global batch
    into that many gradient-accumulation slices (lax.scan) — activation
    memory scales ~1/k with the collective pattern per slice unchanged; the
    standard fix when a cell's temp footprint exceeds HBM (e.g.
    internvl2-76b train_4k, EXPERIMENTS.md §Dry-run)."""
    k = max(1, int(getattr(cfg, "micro_batches", 1)))

    def grad_fn(params, batch):
        return jax.value_and_grad(lm_loss, has_aux=True)(
            params, batch, cfg, specs, aux_weight
        )

    def train_step(params, opt_state, batch):
        if k == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape(k, x.shape[0] // k, *x.shape[1:]), batch
            )
            mb0 = jax.tree.map(lambda x: x[0], micro)

            def body(acc, mb):
                out = grad_fn(params, mb)
                return jax.tree.map(jnp.add, acc, out), None

            zeros = jax.tree.map(
                lambda s: jnp.zeros(s.shape, s.dtype),
                jax.eval_shape(grad_fn, params, mb0),
            )
            ((loss, metrics), grads), _ = jax.lax.scan(body, zeros, micro)
            inv = 1.0 / k
            loss = loss * inv
            grads = jax.tree.map(lambda g: g * inv, grads)
            # sums (nll, token counts) stay sums; only rates would rescale
        params, opt_state = optimizer.update(params, grads, opt_state)
        metrics = dict(metrics, loss=loss)
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg, specs: ActSpecs = ActSpecs()):
    def prefill_step(params, batch):
        logits, _, _ = model_apply(
            cast_params(params, cfg), batch, cfg, mode="prefill", specs=specs
        )
        # return only the last-position logits (next-token) — serving contract
        return logits[:, -1, :]

    return prefill_step


def make_decode_step(cfg, specs: ActSpecs = ActSpecs()):
    def decode_step(params, batch, caches):
        logits, _, new_caches = model_apply(
            cast_params(params, cfg), batch, cfg, mode="decode", specs=specs,
            caches=caches,
        )
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1)
        return next_tok, new_caches

    return decode_step


def greedy_generate(params, cfg, prompt: Array, max_new: int,
                    specs: ActSpecs = ActSpecs()):
    """Reference end-to-end generation (prefill + decode loop) for examples."""
    B, S = prompt.shape
    caches = init_caches(cfg, B, S + max_new)
    decode = make_decode_step(cfg, specs)

    # teacher-forced prefill through the decode path, one token at a time
    # (simple + correct; a production prefill would batch this)
    tok = prompt[:, :1]
    out = [tok]
    for i in range(S + max_new - 1):
        nxt, caches = decode(params, {"tokens": tok}, caches)
        tok = jnp.where(i + 1 < S, prompt[:, i + 1 : i + 2], nxt[:, None])
        out.append(tok)
    return jnp.concatenate(out, axis=1)
