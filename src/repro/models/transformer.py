"""Model composition: blocks, scan-over-layers stacks, full-model init/apply.

Families (configs/base.py):
  dense / vlm  — decoder-only: x += attn(n(x)); x += mlp(n(x))
  moe          — decoder-only with routed-expert FFN (+ shared experts)
  ssm          — mamba blocks: x += ssm(n(x))
  hybrid       — RecurrentGemma: temporal mixer per rglru_pattern + MLP
  encdec       — whisper backbone: encoder (bidir) + decoder (causal + cross)

Homogeneous stacks are scanned (stacked (L, ...) params) and rematerialized
in training — both essential for compile time and memory at 512 devices.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import attention as attn_lib
from . import moe as moe_lib
from . import rglru as rglru_lib
from . import ssm as ssm_lib
from .modules import FSDP, TP, embed_init, layer_norm, linear_init, norm_init, rms_norm, maybe_shard

Array = jax.Array


class ActSpecs(NamedTuple):
    """Activation sharding constraints (resolved mesh axes).

    ``mesh``/``dp``/``tp`` are set when a concrete mesh is known; they enable
    explicitly-scheduled collectives (the a2a MoE dispatch) inside pjit.
    """

    hid: Any = P()    # (B, S, d)   — d replicated
    feat: Any = P()   # (B, S, f)   — f sharded over tp
    exp: Any = P()    # (E, C, d)   — experts sharded over tp
    logits: Any = P() # (B, S, V)   — vocab sharded over tp
    mesh: Any = None  # jax Mesh (optional)
    dp: Any = None    # data-parallel axis name(s), e.g. ('pod', 'data')
    tp: Any = None    # tensor/expert-parallel axis name, e.g. 'model'
    mlp_dp: bool = False  # ZeRO-3-style MLP: tokens stay (dp, sp)-sharded,
                          # weights gathered — zero activation collectives.
                          # Set when tokens/device >> d_ff (see §Perf iter 3)


def pad_vocab(v: int, multiple: int = 256) -> int:
    return -(-v // multiple) * multiple


def _norm(x, scale, cfg, bias=None):
    if cfg.norm == "ln":
        return layer_norm(x, scale, bias)
    return rms_norm(x, scale)


# --------------------------------------------------------------------------
# sub-layer init helpers
# --------------------------------------------------------------------------


def mlp_init(key, cfg, *, stack=None):
    d, ff = cfg.d_model, cfg.d_ff
    k1, k2 = jax.random.split(key)
    params, specs = {}, {}
    width = 2 * ff if cfg.gated_mlp else ff
    params["wi"], specs["wi"] = linear_init(k1, d, width, stack=stack)
    params["wo"], specs["wo"] = linear_init(k2, ff, d, stack=stack, pspec=(TP, FSDP))
    return params, specs


def mlp_apply(p, x, cfg, specs: ActSpecs):
    # two sharding schedules (§Perf iter 3):
    #   tp (Megatron): f shards over model — needs seq all-gather in +
    #     partial-sum all-reduce out, ~2·T_full·d activation bytes/layer.
    #   dp (ZeRO-3 compute): tokens stay (dp, sp)-sharded, weights gathered
    #     (~3·d·ff bytes) — wins when tokens/device >> d_ff.
    h_spec = specs.hid if specs.mlp_dp else specs.feat
    h = maybe_shard(
        jnp.einsum("bsd,df->bsf", x, p["wi"].astype(x.dtype)), h_spec
    )
    if cfg.gated_mlp:
        g, u = jnp.split(h, 2, axis=-1)
        h = jax.nn.silu(g) * u if cfg.act == "silu" else jax.nn.gelu(g) * u
    else:
        h = jax.nn.silu(h) if cfg.act == "silu" else jax.nn.gelu(h)
    return maybe_shard(
        jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(x.dtype)), specs.hid
    )


def _block_init(key, cfg, *, stack, kind: str, cross: bool = False):
    """kind: attn | mla | moe_ffn | ssm | rglru | mlp-only pieces assembled here."""
    ks = jax.random.split(key, 8)
    params: dict[str, Any] = {}
    specs: dict[str, Any] = {}
    params["ln1"], specs["ln1"] = norm_init(cfg.d_model, stack=stack)
    if kind in ("attn", "mla"):
        init = attn_lib.mla_init if kind == "mla" else attn_lib.gqa_init
        params["attn"], specs["attn"] = init(ks[0], cfg, stack=stack)
    elif kind == "ssm":
        params["ssm"], specs["ssm"] = ssm_lib.ssm_init(ks[0], cfg, stack=stack)
        return params, specs  # mamba block has no separate FFN
    elif kind == "rglru":
        params["rec"], specs["rec"] = rglru_lib.rglru_init(ks[0], cfg, stack=stack)
    if cross:
        params["lnx"], specs["lnx"] = norm_init(cfg.d_model, stack=stack)
        params["xattn"], specs["xattn"] = attn_lib.gqa_init(ks[2], cfg, stack=stack)
    params["ln2"], specs["ln2"] = norm_init(cfg.d_model, stack=stack)
    if cfg.n_experts:
        params["moe"], specs["moe"] = moe_lib.moe_init(ks[1], cfg, stack=stack)
    else:
        params["mlp"], specs["mlp"] = mlp_init(ks[1], cfg, stack=stack)
    return params, specs


def _kv_expand_profitable(cfg, specs: ActSpecs) -> bool:
    """Expand KV->H heads before flash attention iff that lets the head dim
    shard over tp where the raw KV count could not (§Perf iter 4). Sharded
    H/tp expanded heads cost LESS per-device memory than replicated KV."""
    if specs.mesh is None or specs.tp is None or not cfg.n_kv_heads:
        return False
    tp_n = int(specs.mesh.shape[specs.tp])
    return (tp_n > 1 and cfg.n_heads % tp_n == 0
            and cfg.n_kv_heads % tp_n != 0
            and cfg.n_heads > cfg.n_kv_heads)


def _block_apply(
    p, x, cfg, specs: ActSpecs, *, kind, mode, positions, cache, window=0,
    enc_out=None,
):
    aux = jnp.zeros((), jnp.float32)
    h = _norm(x, p["ln1"], cfg)
    if kind in ("attn", "mla"):
        fn = attn_lib.mla_apply if kind == "mla" else attn_lib.gqa_apply
        kw = dict(mode=mode, positions=positions, cache=cache,
                  act_spec=specs.feat, out_spec=specs.hid,
                  full_specs=specs)
        if kind == "attn":
            kw["window"] = window
            kw["kv_expand"] = _kv_expand_profitable(cfg, specs)
        y, new_cache = fn(p["attn"], h, cfg, **kw)
    elif kind == "ssm":
        y, new_cache = ssm_lib.ssm_apply(
            p["ssm"], h, cfg, mode=mode, cache=cache, act_spec=specs.feat
        )
        return x + y, new_cache, aux
    elif kind == "rglru":
        y, new_cache = rglru_lib.rglru_apply(
            p["rec"], h, cfg, mode=mode, cache=cache, act_spec=specs.feat
        )
    else:
        raise ValueError(kind)
    x = x + y
    if enc_out is not None and "xattn" in p:
        hx = _norm(x, p["lnx"], cfg)
        y, _ = attn_lib.gqa_apply(
            p["xattn"], hx, cfg, mode="encode", kv_src=enc_out,
            act_spec=specs.feat, out_spec=specs.hid,
        )
        x = x + y
    h2 = _norm(x, p["ln2"], cfg)
    if "moe" in p:
        y2, aux = moe_lib.moe_apply(p["moe"], h2, cfg, specs=specs)
        y2 = maybe_shard(y2, specs.hid)
    else:
        y2 = mlp_apply(p["mlp"], h2, cfg, specs)
    return x + y2, new_cache, aux


# --------------------------------------------------------------------------
# full models
# --------------------------------------------------------------------------


def layer_kind(cfg) -> str:
    """Temporal-mixer kind; the FFN flavor (dense vs MoE) follows cfg.n_experts."""
    if cfg.family == "ssm":
        return "ssm"
    if cfg.attn == "mla":
        return "mla"
    return "attn"


def init_model(key, cfg):
    ks = jax.random.split(key, 8)
    Vp = pad_vocab(cfg.vocab)
    params: dict[str, Any] = {}
    specs: dict[str, Any] = {}
    params["embed"], specs["embed"] = embed_init(ks[0], Vp, cfg.d_model)
    params["final_ln"], specs["final_ln"] = norm_init(cfg.d_model)
    if not cfg.tie_embeddings:
        params["lm_head"], specs["lm_head"] = linear_init(
            ks[1], cfg.d_model, Vp, pspec=(FSDP, TP)
        )

    kind = layer_kind(cfg)
    if cfg.family == "hybrid":
        pat = cfg.rglru_pattern or ("rec", "rec", "attn")
        period = len(pat)
        n_super = cfg.n_layers // period
        rest = cfg.n_layers % period
        sub_p, sub_s = {}, {}
        for i, knd in enumerate(pat):
            kk = "rglru" if knd == "rec" else "attn"
            sub_p[f"b{i}"], sub_s[f"b{i}"] = _block_init(
                jax.random.fold_in(ks[2], i), cfg, stack=n_super, kind=kk
            )
        params["superblocks"], specs["superblocks"] = sub_p, sub_s
        tail_p, tail_s = {}, {}
        for i in range(rest):
            kk = "rglru" if pat[i] == "rec" else "attn"
            tail_p[f"t{i}"], tail_s[f"t{i}"] = _block_init(
                jax.random.fold_in(ks[3], i), cfg, stack=None, kind=kk
            )
        params["tail"], specs["tail"] = tail_p, tail_s
    elif cfg.family == "encdec":
        params["enc_embed"] = 0.02 * jax.random.normal(
            ks[4], (cfg.enc_seq, cfg.d_model), jnp.float32
        )
        specs["enc_embed"] = P(None, None)
        params["enc_layers"], specs["enc_layers"] = _block_init(
            ks[5], cfg, stack=cfg.n_enc_layers, kind="attn"
        )
        params["layers"], specs["layers"] = _block_init(
            ks[6], cfg, stack=cfg.n_layers, kind="attn", cross=True
        )
        params["enc_final_ln"], specs["enc_final_ln"] = norm_init(cfg.d_model)
    else:
        params["layers"], specs["layers"] = _block_init(
            ks[7], cfg, stack=cfg.n_layers, kind=kind
        )
    return params, specs


def _scan_stack(layers_p, x, cfg, specs, *, kind, mode, positions, caches,
                window_pattern=None, enc_out=None):
    """Scan over stacked layer params; caches is a stacked pytree or None."""
    use_remat = cfg.remat and mode == "train"

    def body(carry, xs):
        x, aux = carry
        lp, cache = xs

        def f(x):
            return _block_apply(
                lp, x, cfg, specs, kind=kind, mode=mode, positions=positions,
                cache=cache, enc_out=enc_out,
            )

        if use_remat:
            f = jax.checkpoint(f)
        x, new_cache, aux_l = f(x)
        return (x, aux + aux_l), new_cache

    (x, aux), new_caches = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), (layers_p, caches),
        unroll=True if cfg.unroll_layers else 1,
    )
    return x, aux, new_caches


def model_apply(params, batch, cfg, *, mode: str, specs: ActSpecs = ActSpecs(),
                caches=None):
    """Returns (logits, aux_loss, new_caches)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    Vp = pad_vocab(cfg.vocab)
    x = params["embed"][tokens]
    x = x.astype(jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)
    x = maybe_shard(x, specs.hid)

    if cfg.family == "vlm" and "patches" in batch:
        pe = batch["patches"].astype(x.dtype)  # (B, Pimg, d) vision stub
        x = jnp.concatenate([pe, x[:, pe.shape[1]:]], axis=1) \
            if mode != "decode" else x

    if mode == "decode":
        positions = jnp.broadcast_to(
            _cache_length(caches, cfg)[None, None], (B, 1)
        ).astype(jnp.int32)
    else:
        positions = jnp.arange(S, dtype=jnp.int32)[None, :]

    aux = jnp.zeros((), jnp.float32)
    kind = layer_kind(cfg)

    if cfg.family == "encdec":
        enc_out = None
        if "enc_out" in batch:  # serving: encoder ran once at prefill
            enc_out = batch["enc_out"].astype(x.dtype)
        elif "frames" in batch:
            e = batch["frames"].astype(x.dtype) + params["enc_embed"][None].astype(
                x.dtype
            )
            e = maybe_shard(e, specs.hid)
            e, _, _ = _scan_stack(
                params["enc_layers"], e, cfg, specs, kind="attn", mode="encode",
                positions=jnp.arange(e.shape[1], dtype=jnp.int32)[None, :],
                caches=None,
            )
            enc_out = _norm(e, params["enc_final_ln"], cfg)
        x, aux, new_caches = _scan_stack(
            params["layers"], x, cfg, specs, kind="attn", mode=mode,
            positions=positions, caches=caches, enc_out=enc_out,
        )
    elif cfg.family == "hybrid":
        x, aux, new_caches = _hybrid_apply(
            params, x, cfg, specs, mode=mode, positions=positions, caches=caches
        )
    else:
        x, aux, new_caches = _scan_stack(
            params["layers"], x, cfg, specs, kind=kind, mode=mode,
            positions=positions, caches=caches,
        )

    x = _norm(x, params["final_ln"], cfg)
    head = (
        params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    )
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))
    logits = maybe_shard(logits, specs.logits)
    return logits.astype(jnp.float32), aux, new_caches


def _hybrid_apply(params, x, cfg, specs, *, mode, positions, caches):
    pat = cfg.rglru_pattern or ("rec", "rec", "attn")
    period = len(pat)
    n_super = cfg.n_layers // period
    rest = cfg.n_layers % period
    aux = jnp.zeros((), jnp.float32)
    use_remat = cfg.remat and mode == "train"

    def body(carry, xs):
        x, aux = carry
        lps, lcaches = xs

        def f(x):
            new_caches = []
            for i, kn in enumerate(pat):
                kk = "rglru" if kn == "rec" else "attn"
                c = lcaches[i] if lcaches is not None else None
                x, nc, _ = _block_apply(
                    lps[f"b{i}"], x, cfg, specs, kind=kk, mode=mode,
                    positions=positions, cache=c,
                    window=cfg.local_window if kk == "attn" else 0,
                )
                new_caches.append(nc)
            return x, new_caches

        if use_remat:
            f = jax.checkpoint(f)
        x, new_caches = f(x)
        ncs = None if new_caches[0] is None else tuple(new_caches)
        return (x, aux), ncs

    sup_caches = caches[0] if caches is not None else None
    (x, aux), new_sup = jax.lax.scan(
        body, (x, aux), (params["superblocks"], sup_caches),
        unroll=True if cfg.unroll_layers else 1,
    )
    new_tail = []
    for i in range(rest):
        kk = "rglru" if pat[i] == "rec" else "attn"
        c = caches[1][i] if caches is not None else None
        x, nc, _ = _block_apply(
            params["tail"][f"t{i}"], x, cfg, specs, kind=kk, mode=mode,
            positions=positions, cache=c,
            window=cfg.local_window if kk == "attn" else 0,
        )
        new_tail.append(nc)
    ncs = None if caches is None else (new_sup, tuple(new_tail))
    return x, aux, ncs


# --------------------------------------------------------------------------
# caches
# --------------------------------------------------------------------------


def _stack_caches(make_one, L):
    """Build stacked (L, ...) caches by vmapping the constructor."""
    return jax.vmap(lambda _: make_one())(jnp.arange(L))


def init_caches(cfg, B: int, S: int):
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    kind = layer_kind(cfg)
    if cfg.family == "hybrid":
        pat = cfg.rglru_pattern or ("rec", "rec", "attn")
        period = len(pat)
        n_super = cfg.n_layers // period
        rest = cfg.n_layers % period

        def make(kn):
            if kn == "rec":
                return lambda: rglru_lib.init_rglru_cache(cfg, B, dtype)
            return lambda: attn_lib.init_gqa_cache(
                cfg, B, S, dtype, window=cfg.local_window
            )

        sup = tuple(_stack_caches(make(kn), n_super) for kn in pat)
        tail = tuple(make(pat[i])() for i in range(rest))
        return (sup, tail)
    if kind == "ssm":
        return _stack_caches(lambda: ssm_lib.init_ssm_cache(cfg, B, dtype),
                             cfg.n_layers)
    if kind == "mla":
        return _stack_caches(lambda: attn_lib.init_mla_cache(cfg, B, S, dtype),
                             cfg.n_layers)
    return _stack_caches(lambda: attn_lib.init_gqa_cache(cfg, B, S, dtype),
                         cfg.n_layers)


def _cache_length(caches, cfg):
    leaf = jax.tree.leaves(caches)
    # every cache carries a scalar length as its last leaf per layer; take any
    for x in jax.tree.leaves(caches):
        if x.dtype == jnp.int32:
            return x.reshape(-1)[0]
    return jnp.zeros((), jnp.int32)
