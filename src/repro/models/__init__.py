from .transformer import (
    ActSpecs,
    init_caches,
    init_model,
    model_apply,
    pad_vocab,
)
from .lm import (
    cross_entropy,
    greedy_generate,
    lm_loss,
    make_decode_step,
    make_prefill_step,
    make_train_step,
)

__all__ = [
    "ActSpecs",
    "init_caches",
    "init_model",
    "model_apply",
    "pad_vocab",
    "cross_entropy",
    "greedy_generate",
    "lm_loss",
    "make_decode_step",
    "make_prefill_step",
    "make_train_step",
]
