"""Mamba-1 selective SSM (falcon-mamba-7b), TPU-adapted.

The CUDA selective-scan kernel does a fused sequential scan in SRAM. The TPU
re-think (DESIGN.md §4): chunk the sequence into ``scan_chunk`` blocks, run an
associative scan *within* each chunk (parallel, VMEM-sized (B, Lc, di, n)
materialization), and carry the (B, di, n) state across chunks with lax.scan.
This keeps memory O(Lc · di · n) instead of O(S · di · n) and exposes MXU
parallelism inside chunks.

Decode is O(1) in sequence length: the cache is (conv window, ssm state) —
this is why falcon-mamba runs the ``long_500k`` cell.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .modules import FSDP, TP, linear_init, maybe_shard

Array = jax.Array


class SSMCache(NamedTuple):
    conv: Array   # (B, conv_k - 1, di) — last inputs for the causal conv
    h: Array      # (B, di, n) — ssm state
    length: Array


def ssm_init(key, cfg, *, stack: int | None = None):
    d, di, n, dtr, ck = (
        cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank, cfg.ssm_conv
    )
    ks = jax.random.split(key, 7)
    params, specs = {}, {}
    params["in_proj"], specs["in_proj"] = linear_init(ks[0], d, 2 * di, stack=stack)
    conv_shape = (ck, di) if stack is None else (stack, ck, di)
    params["conv_w"] = 0.1 * jax.random.normal(ks[1], conv_shape, jnp.float32)
    specs["conv_w"] = P(*((None,) * (len(conv_shape) - 1) + (TP,)))
    params["x_proj"], specs["x_proj"] = linear_init(
        ks[2], di, dtr + 2 * n, stack=stack, pspec=(TP, None)
    )
    params["dt_proj"], specs["dt_proj"] = linear_init(
        ks[3], dtr, di, stack=stack, pspec=(None, TP)
    )
    alog_shape = (di, n) if stack is None else (stack, di, n)
    params["A_log"] = jnp.log(
        jnp.broadcast_to(1.0 + jnp.arange(n, dtype=jnp.float32), alog_shape)
    )
    specs["A_log"] = P(*((None,) * (len(alog_shape) - 2) + (TP, None)))
    dshape = (di,) if stack is None else (stack, di)
    params["D"] = jnp.ones(dshape, jnp.float32)
    specs["D"] = P(*((None,) * (len(dshape) - 1) + (TP,)))
    params["out_proj"], specs["out_proj"] = linear_init(
        ks[5], di, d, stack=stack, pspec=(TP, FSDP)
    )
    return params, specs


def _ssm_scan_chunked(a: Array, bx: Array, h0: Array, chunk: int):
    """h_t = a_t * h_{t-1} + bx_t over axis 1. a, bx: (B, S, di, n)."""
    B, S, di, n = a.shape
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:  # tail padding: outputs beyond S are sliced away below
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        bx = jnp.pad(bx, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = S + pad
    n_chunks = Sp // chunk
    a_c = a.reshape(B, n_chunks, chunk, di, n).transpose(1, 0, 2, 3, 4)
    bx_c = bx.reshape(B, n_chunks, chunk, di, n).transpose(1, 0, 2, 3, 4)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    def body(h, ab):
        a_j, bx_j = ab  # (B, Lc, di, n)
        aa, bb = jax.lax.associative_scan(combine, (a_j, bx_j), axis=1)
        # fold in the carried state: h_t = aa_t * h0 + bb_t
        hs = aa * h[:, None] + bb
        return hs[:, -1], hs

    h_last, hs = jax.lax.scan(body, h0, (a_c, bx_c))
    hs = hs.transpose(1, 0, 2, 3, 4).reshape(B, Sp, di, n)[:, :S]
    return hs, h_last


def _causal_conv(x: Array, w: Array, history: Array | None = None):
    """Depthwise causal conv along axis 1. x (B,S,di), w (ck,di)."""
    ck = w.shape[0]
    if history is None:
        xp = jnp.pad(x, ((0, 0), (ck - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([history, x], axis=1)
    out = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(ck)
    )
    return out.astype(x.dtype)


def ssm_apply(
    p: dict,
    x: Array,           # (B, S, d)
    cfg,
    *,
    mode: str,
    cache: SSMCache | None = None,
    act_spec=P(),
) -> tuple[Array, SSMCache | None]:
    B, S, d = x.shape
    di, n, dtr, ck = cfg.d_inner, cfg.ssm_state, cfg.dt_rank, cfg.ssm_conv

    xz = maybe_shard(
        jnp.einsum("bsd,df->bsf", x, p["in_proj"]), act_spec
    )
    xin, z = jnp.split(xz, 2, axis=-1)  # (B, S, di) each

    history = cache.conv if mode == "decode" and cache is not None else None
    xc = _causal_conv(xin, p["conv_w"], history)
    xc = jax.nn.silu(xc)

    proj = jnp.einsum("bsf,fg->bsg", xc, p["x_proj"])  # (B,S,dtr+2n)
    dt_r, b_ssm, c_ssm = jnp.split(proj, [dtr, dtr + n], axis=-1)
    dt = jax.nn.softplus(jnp.einsum("bsr,rf->bsf", dt_r, p["dt_proj"]))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))          # (di, n)

    dtA = dt.astype(jnp.float32)[..., None] * A[None, None]      # (B,S,di,n)
    a_bar = jnp.exp(dtA)
    bx = (
        dt.astype(jnp.float32)[..., None]
        * b_ssm.astype(jnp.float32)[:, :, None, :]
        * xc.astype(jnp.float32)[..., None]
    )                                                            # (B,S,di,n)

    if mode == "decode":
        assert cache is not None and S == 1
        h = a_bar[:, 0] * cache.h + bx[:, 0]                     # (B,di,n)
        y = jnp.einsum("bdn,bn->bd", h, c_ssm[:, 0].astype(jnp.float32))
        y = y[:, None, :]
        new_conv = jnp.concatenate([cache.conv, xin], axis=1)[:, 1:]
        new_cache = SSMCache(new_conv, h, cache.length + 1)
    else:
        h0 = jnp.zeros((B, di, n), jnp.float32)
        hs, _ = _ssm_scan_chunked(a_bar, bx, h0, cfg.scan_chunk)
        y = jnp.einsum("bsdn,bsn->bsd", hs, c_ssm.astype(jnp.float32))
        new_cache = None

    y = y + xc.astype(jnp.float32) * p["D"][None, None].astype(jnp.float32)
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = maybe_shard(
        jnp.einsum("bsf,fd->bsd", y, p["out_proj"]), act_spec
    )
    return out, new_cache


def init_ssm_cache(cfg, B: int, dtype):
    return SSMCache(
        conv=jnp.zeros((B, cfg.ssm_conv - 1, cfg.d_inner), dtype),
        h=jnp.zeros((B, cfg.d_inner, cfg.ssm_state), jnp.float32),
        length=jnp.zeros((), jnp.int32),
    )
