"""Attention flavors: GQA/MHA, MLA (DeepSeek/MiniCPM), local-window (RG).

Shapes: activations (B, S, d). Projections are flattened-feature GEMMs with
TP sharding constraints on the flattened dim (always divisible by the model
axis for the assigned archs — see DESIGN.md §6); 4-D internals are left to
the SPMD partitioner.

Prefill/train uses flash-style chunked attention (lax.scan over KV chunks
with online softmax) so the S x S score matrix never materializes. Decode is
a single-token read over a static-length cache. MLA decode uses the
*absorbed-weights* form (q projected into the latent space, context read in
latent space) — the KV cache is (kv_lora + rope) wide instead of
2 * H * hd (a beyond-paper serving optimization; see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .modules import FSDP, TP, linear_init, rope, maybe_shard, sp_out_proj

Array = jax.Array
NEG_INF = -1e30


class KVCache(NamedTuple):
    k: Array          # (B, S_cache, KV, hd)  — GQA; MLA: c_kv (B, S, r)
    v: Array          # (B, S_cache, KV, hd)  — GQA; MLA: k_rope (B, S, rd)
    length: Array     # () int32 — valid prefix length


def _shard(x: Array, spec) -> Array:
    return maybe_shard(x, spec)


# --------------------------------------------------------------------------
# chunked (flash-style) softmax attention
# --------------------------------------------------------------------------


def chunked_attention(
    q: Array,             # (B, Sq, KV, G, hd)
    k: Array,             # (B, Sk, KV, hd)
    v: Array,             # (B, Sk, KV, hd)
    *,
    chunk: int,
    causal: bool,
    q_offset: Array | int = 0,   # position of q[0] in the kv timeline
    window: int = 0,             # 0 = global
) -> Array:
    B, Sq, KV, G, hd = q.shape
    Sk = k.shape[1]
    chunk = min(chunk, Sk)
    n_chunks = -(-Sk // chunk)
    pad = n_chunks * chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    scale = hd ** -0.5
    qf = q.astype(jnp.float32) * scale
    q_pos = jnp.arange(Sq) + q_offset  # (Sq,)

    def body(carry, j):
        m, l, acc = carry
        kj = jax.lax.dynamic_slice_in_dim(k, j * chunk, chunk, axis=1)
        vj = jax.lax.dynamic_slice_in_dim(v, j * chunk, chunk, axis=1)
        s = jnp.einsum(
            "bqkgh,bckh->bkgqc", qf, kj.astype(jnp.float32)
        )  # (B, KV, G, Sq, C)
        k_pos = j * chunk + jnp.arange(chunk)
        valid = k_pos[None, :] < Sk
        if causal:
            valid = valid & (q_pos[:, None] >= k_pos[None, :])
        if window:
            valid = valid & (q_pos[:, None] - k_pos[None, :] < window)
        s = jnp.where(valid[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgqc,bckh->bkgqh", p, vj.astype(jnp.float32))
        acc = acc * corr[..., None] + pv
        return (m_new, l, acc), None

    hd_v = v.shape[-1]  # may differ from q/k head dim (MLA: nope+rope vs v)
    m0 = jnp.full((B, KV, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, KV, G, Sq, hd_v), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), jnp.arange(n_chunks))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    # (B, KV, G, Sq, hd) -> (B, Sq, KV, G, hd)
    return out.transpose(0, 3, 1, 2, 4).astype(q.dtype)


def decode_attention(
    q: Array,       # (B, 1, KV, G, hd)
    k: Array,       # (B, S, KV, hd)
    v: Array,       # (B, S, KV, hd)
    length: Array,  # () valid cache length (new token at index length-1)
    window: int = 0,
) -> Array:
    S = k.shape[1]
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum(
        "bqkgh,bckh->bkgqc", q.astype(jnp.float32) * scale, k.astype(jnp.float32)
    )
    pos = jnp.arange(S)
    valid = pos < length
    if window:
        valid = valid & (pos >= length - window)
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqc,bckh->bkgqh", p, v.astype(jnp.float32))
    return out.transpose(0, 3, 1, 2, 4).astype(q.dtype)


# --------------------------------------------------------------------------
# GQA block
# --------------------------------------------------------------------------


def gqa_init(key, cfg, *, stack: int | None = None, cross: bool = False):
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    params, specs = {}, {}
    params["wq"], specs["wq"] = linear_init(ks[0], d, H * hd, stack=stack)
    params["wk"], specs["wk"] = linear_init(ks[1], d, KV * hd, stack=stack)
    params["wv"], specs["wv"] = linear_init(ks[2], d, KV * hd, stack=stack)
    params["wo"], specs["wo"] = linear_init(
        ks[3], H * hd, d, stack=stack, pspec=(TP, FSDP)
    )
    return params, specs


def gqa_apply(
    p: dict,
    x: Array,                  # (B, S, d)
    cfg,
    *,
    mode: str,                 # train | prefill | decode
    positions: Array | None = None,
    cache: KVCache | None = None,
    kv_src: Array | None = None,   # cross-attention source (enc-dec)
    window: int = 0,
    act_spec=P(),
    out_spec=P(),
    kv_expand: bool = False,       # broadcast KV->H heads pre-attention so the
                                   # flash carry shards cleanly over tp
                                   # (§Perf iter 4: set when H%tp==0, KV%tp!=0)
    full_specs=None,               # ActSpecs with mesh axes (§Perf iter 5)
) -> tuple[Array, KVCache | None]:
    B, S, d = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    G = H // KV
    src = x if kv_src is None else kv_src
    q = _shard(jnp.einsum("bsd,df->bsf", x, p["wq"]), act_spec)
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q = rope(q.reshape(B, S, H, hd), positions, cfg.rope_theta)
    q = q.reshape(B, S, KV, G, hd)

    if mode == "decode":
        assert cache is not None
        k_new = jnp.einsum("bsd,df->bsf", src, p["wk"]).reshape(B, S, KV, hd)
        v_new = jnp.einsum("bsd,df->bsf", src, p["wv"]).reshape(B, S, KV, hd)
        k_new = rope(k_new, positions, cfg.rope_theta)
        if window and cache.k.shape[1] == window:
            # ring buffer (local attention): write at length % window
            slot = cache.length % window
            k_cache = jax.lax.dynamic_update_slice_in_dim(cache.k, k_new, slot, 1)
            v_cache = jax.lax.dynamic_update_slice_in_dim(cache.v, v_new, slot, 1)
            # ring semantics: everything in the buffer is valid once warm
            out = _ring_decode(q, k_cache, v_cache, cache.length + 1, window)
            new_cache = KVCache(k_cache, v_cache, cache.length + 1)
        else:
            k_cache = jax.lax.dynamic_update_slice_in_dim(
                cache.k, k_new, cache.length, 1
            )
            v_cache = jax.lax.dynamic_update_slice_in_dim(
                cache.v, v_new, cache.length, 1
            )
            out = decode_attention(q, k_cache, v_cache, cache.length + 1, window)
            new_cache = KVCache(k_cache, v_cache, cache.length + 1)
    else:
        if kv_src is None:
            k = jnp.einsum("bsd,df->bsf", src, p["wk"]).reshape(B, S, KV, hd)
            kv_pos = positions
        else:
            Sk = src.shape[1]
            k = jnp.einsum("bsd,df->bsf", src, p["wk"]).reshape(B, Sk, KV, hd)
            kv_pos = jnp.arange(Sk)[None, :]
        k = rope(k, kv_pos, cfg.rope_theta)
        v = jnp.einsum("bsd,df->bsf", src, p["wv"]).reshape(
            B, src.shape[1], KV, hd
        )
        causal = kv_src is None and mode != "encode"
        if kv_expand and G > 1:
            # (B,S,KV,hd) -> (B,S,H,hd): head h = kv*G + g, matching q's
            # reshape order. The (m,l,acc) flash carry then has a single
            # H head-dim that shards over tp — avoids the SPMD
            # replicate-then-repartition of the (KV,G) pair each chunk —
            # and per-device KV bytes DROP (H/tp sharded < KV replicated).
            k = jnp.repeat(k, G, axis=2)
            v = jnp.repeat(v, G, axis=2)
            out = chunked_attention(
                q.reshape(B, S, H, 1, hd), k, v,
                chunk=cfg.attn_chunk, causal=causal, window=window,
            ).reshape(B, S, KV, G, hd)
        else:
            out = chunked_attention(
                q, k, v, chunk=cfg.attn_chunk, causal=causal, window=window
            )
        new_cache = None

    out = out.reshape(B, S, H * hd)
    if (full_specs is not None and mode == "train"
            and len(out_spec) > 1 and out_spec[1] is not None):
        # SP-sharded residual: reduce-scatter the partial sums explicitly
        y = sp_out_proj(out, p["wo"].astype(out.dtype), full_specs, out_spec)
    else:
        y = _shard(jnp.einsum("bsf,fd->bsd", out, p["wo"]), out_spec)
    return y, new_cache


def _ring_decode(q, k, v, length, window):
    """Decode attention over a ring buffer: all slots valid once length>=window."""
    S = k.shape[1]
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum(
        "bqkgh,bckh->bkgqc", q.astype(jnp.float32) * scale, k.astype(jnp.float32)
    )
    pos = jnp.arange(S)
    valid = jnp.where(length >= window, jnp.ones((S,), bool), pos < length)
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqc,bckh->bkgqh", p, v.astype(jnp.float32))
    return out.transpose(0, 3, 1, 2, 4).astype(q.dtype)


# --------------------------------------------------------------------------
# MLA block (DeepSeek-V2 / MiniCPM3)
# --------------------------------------------------------------------------


def mla_init(key, cfg, *, stack: int | None = None):
    d, H, hd = cfg.d_model, cfg.n_heads, cfg.hd
    r = cfg.kv_lora_rank
    rd = cfg.qk_rope_dim
    nd = cfg.qk_nope_dim or hd
    ks = jax.random.split(key, 7)
    params, specs = {}, {}
    if cfg.q_lora_rank:
        params["wdq"], specs["wdq"] = linear_init(
            ks[0], d, cfg.q_lora_rank, stack=stack, pspec=(FSDP, None)
        )
        params["wuq"], specs["wuq"] = linear_init(
            ks[1], cfg.q_lora_rank, H * (nd + rd), stack=stack
        )
    else:
        params["wq"], specs["wq"] = linear_init(ks[1], d, H * (nd + rd), stack=stack)
    params["wdkv"], specs["wdkv"] = linear_init(
        ks[2], d, r, stack=stack, pspec=(FSDP, None)
    )
    params["wkr"], specs["wkr"] = linear_init(
        ks[3], d, rd, stack=stack, pspec=(FSDP, None)
    )
    params["wuk"], specs["wuk"] = linear_init(ks[4], r, H * nd, stack=stack)
    params["wuv"], specs["wuv"] = linear_init(ks[5], r, H * hd, stack=stack)
    params["wo"], specs["wo"] = linear_init(
        ks[6], H * hd, d, stack=stack, pspec=(TP, FSDP)
    )
    return params, specs


def mla_apply(
    p: dict,
    x: Array,
    cfg,
    *,
    mode: str,
    positions: Array | None = None,
    cache: KVCache | None = None,
    act_spec=P(),
    out_spec=P(),
    full_specs=None,               # ActSpecs with mesh axes (§Perf iter 5)
) -> tuple[Array, KVCache | None]:
    B, S, d = x.shape
    H, hd = cfg.n_heads, cfg.hd
    r, rd = cfg.kv_lora_rank, cfg.qk_rope_dim
    nd = cfg.qk_nope_dim or hd
    if positions is None:
        positions = jnp.arange(S)[None, :]

    if cfg.q_lora_rank:
        q = jnp.einsum("bsd,dr->bsr", x, p["wdq"])
        q = _shard(jnp.einsum("bsr,rf->bsf", q, p["wuq"]), act_spec)
    else:
        q = _shard(jnp.einsum("bsd,df->bsf", x, p["wq"]), act_spec)
    q = q.reshape(B, S, H, nd + rd)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)

    c_new = jnp.einsum("bsd,dr->bsr", x, p["wdkv"])       # latent KV
    kr_new = rope(
        jnp.einsum("bsd,dr->bsr", x, p["wkr"]), positions, cfg.rope_theta
    )

    if mode == "decode":
        assert cache is not None
        c = jax.lax.dynamic_update_slice_in_dim(cache.k, c_new, cache.length, 1)
        kr = jax.lax.dynamic_update_slice_in_dim(cache.v, kr_new, cache.length, 1)
        length = cache.length + 1
        # absorbed form: score in latent space
        wuk = p["wuk"].reshape(r, H, nd)
        q_lat = jnp.einsum("bshn,rhn->bshr", q_nope, wuk)   # (B,1,H,r)
        scale = (nd + rd) ** -0.5
        s = (
            jnp.einsum("bshr,bcr->bhsc", q_lat, c)
            + jnp.einsum("bshr,bcr->bhsc", q_rope, kr)
        ) * scale
        pos = jnp.arange(c.shape[1])
        s = jnp.where((pos < length)[None, None, None, :], s, NEG_INF)
        w = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
        ctx = jnp.einsum("bhsc,bcr->bshr", w, c.astype(jnp.float32))  # latent ctx
        wuv = p["wuv"].reshape(r, H, hd)
        out = jnp.einsum("bshr,rhv->bshv", ctx.astype(x.dtype), wuv)
        new_cache = KVCache(c, kr, length)
    else:
        k_nope = jnp.einsum("bsr,rf->bsf", c_new, p["wuk"]).reshape(B, S, H, nd)
        v = jnp.einsum("bsr,rf->bsf", c_new, p["wuv"]).reshape(B, S, H, hd)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(kr_new[:, :, None, :], (B, S, H, rd))],
            axis=-1,
        )
        qq = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = chunked_attention(
            qq.reshape(B, S, H, 1, nd + rd),
            k,
            v,
            chunk=cfg.attn_chunk,
            causal=True,
        ).reshape(B, S, H, hd)
        new_cache = None

    out2 = out.reshape(B, S, H * hd)
    if (full_specs is not None and mode == "train"
            and len(out_spec) > 1 and out_spec[1] is not None):
        y = sp_out_proj(out2, p["wo"].astype(out2.dtype), full_specs, out_spec)
    else:
        y = _shard(jnp.einsum("bsf,fd->bsd", out2, p["wo"]), out_spec)
    return y, new_cache


def init_gqa_cache(cfg, B: int, S: int, dtype, window: int = 0):
    KV, hd = cfg.n_kv_heads, cfg.hd
    Sc = min(S, window) if window else S
    return KVCache(
        k=jnp.zeros((B, Sc, KV, hd), dtype),
        v=jnp.zeros((B, Sc, KV, hd), dtype),
        length=jnp.zeros((), jnp.int32),
    )


def init_mla_cache(cfg, B: int, S: int, dtype):
    return KVCache(
        k=jnp.zeros((B, S, cfg.kv_lora_rank), dtype),
        v=jnp.zeros((B, S, cfg.qk_rope_dim), dtype),
        length=jnp.zeros((), jnp.int32),
    )
