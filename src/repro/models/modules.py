"""Minimal pure-JAX module primitives (no flax): param init + apply fns.

Params are nested dicts of jax.Arrays. Every init fn returns (params, pspec)
where pspec mirrors the param tree with jax.sharding.PartitionSpec leaves —
sharding is declared next to the parameter it belongs to, so the launcher can
pjit any model without model-specific knowledge.

Axis-name conventions used in pspecs (resolved by parallel/mesh.py):
  "fsdp"   -> data(+pod) axes when FSDP is on, else None
  "tp"     -> the model/tensor axis
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat

Array = jax.Array

# logical axis placeholders; parallel/mesh.py maps them to mesh axes
FSDP = "__fsdp__"
TP = "__tp__"


def truncated_normal_init(key, shape, scale, dtype=jnp.float32):
    # fan-in scaled truncated normal (MaxText-style default)
    stddev = scale / max(1.0, (shape[-2] if len(shape) >= 2 else shape[-1])) ** 0.5
    return stddev * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


def linear_init(key, d_in, d_out, *, stack=None, dtype=jnp.float32,
                pspec=(FSDP, TP)):
    shape = (d_in, d_out) if stack is None else (stack, d_in, d_out)
    w = truncated_normal_init(key, shape, 1.0, dtype)
    spec = P(*(((None,) * (len(shape) - 2)) + tuple(pspec)))
    return w, spec


def embed_init(key, vocab, d, *, dtype=jnp.float32):
    w = truncated_normal_init(key, (vocab, d), 1.0, dtype)
    return w, P(TP, None)


def norm_init(d, *, stack=None, dtype=jnp.float32):
    shape = (d,) if stack is None else (stack, d)
    w = jnp.ones(shape, dtype)
    return w, P(*((None,) * len(shape)))


def rms_norm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x: Array, scale: Array, bias: Array | None = None,
               eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(x.dtype)


def rope(x: Array, positions: Array, theta: float = 10000.0,
         rope_dim: int | None = None) -> Array:
    """Rotary embedding. x: (..., S, H, hd) or (..., S, hd); positions (..., S)."""
    hd = x.shape[-1]
    rd = rope_dim or hd
    half = rd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, half)
    if x.ndim == ang.ndim + 1:  # head dim present
        ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    xr = x[..., :rd].astype(jnp.float32)
    x1, x2 = xr[..., :half], xr[..., half:]
    rotated = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([rotated.astype(x.dtype), x[..., rd:]], axis=-1)


def sp_out_proj(h: Array, w: Array, specs, fallback_spec) -> Array:
    """Feature-contracting out-projection with an EXPLICIT reduce-scatter.

    h: (B, S, f) with f tp-sharded; w: (f, d). The auto-SPMD lowering of
    ``einsum + sharding_constraint`` emits all-reduce + slice (the ar->rs
    rewrite is a TPU-pipeline pass we cannot rely on); this shard_map issues
    ``psum_scatter`` over the sequence dim directly — (tp-1)/tp fewer bytes
    on the wire per call (§Perf iter 5). Falls back to the constrained
    einsum whenever the shapes/mesh don't divide.
    """
    mesh, dp, tp = getattr(specs, "mesh", None), getattr(specs, "dp", None), \
        getattr(specs, "tp", None)
    B, S, f = h.shape
    d = w.shape[-1]
    if mesh is None or tp is None:
        return maybe_shard(jnp.einsum("bsf,fd->bsd", h, w), fallback_spec)
    tp_n = int(mesh.shape[tp])
    dp_axes = dp if isinstance(dp, tuple) else ((dp,) if dp else ())
    import numpy as _np
    dp_n = int(_np.prod([mesh.shape[a] for a in dp_axes])) if dp_axes else 1
    if tp_n <= 1 or S % tp_n or f % tp_n:
        return maybe_shard(jnp.einsum("bsf,fd->bsd", h, w), fallback_spec)
    bdim = dp if (dp_axes and B % dp_n == 0) else None

    def local(h_loc, w_loc):
        y = jnp.einsum("bsf,fd->bsd", h_loc, w_loc)   # partial sum over f
        return jax.lax.psum_scatter(y, tp, scatter_dimension=1, tiled=True)

    return compat.shard_map(
        local,
        mesh=mesh,
        in_specs=(P(bdim, None, tp), P(tp, None)),
        out_specs=P(bdim, tp, None),
        check_vma=False,
    )(h, w)


def maybe_shard(x: Array, spec) -> Array:
    """Shape-aware with_sharding_constraint.

    No-op without a mesh context (single-device tests); under a mesh, spec
    entries whose axis product does not divide the dim fall back to
    replication (e.g. whisper's 1500-frame encoder under 16-way SP).
    """
    if spec is None or not isinstance(spec, P) or all(e is None for e in spec):
        return x
    mesh = compat.get_abstract_mesh()
    if mesh is None or not mesh.axis_names:
        return x
    fixed = []
    for i, e in enumerate(spec):
        if e is not None and i < x.ndim:
            axes = (e,) if isinstance(e, str) else tuple(e)
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            if x.shape[i] % size != 0:
                e = None
        fixed.append(e)
    if all(e is None for e in fixed):
        return x
    return jax.lax.with_sharding_constraint(x, P(*fixed))


def resolve_pspec(tree: Any, *, fsdp_axes, tp_axis) -> Any:
    """Map FSDP/TP placeholders in a pspec tree to concrete mesh axes."""

    def fix(spec):
        if not isinstance(spec, P):
            return spec
        out = []
        for e in spec:
            if e == FSDP:
                out.append(fsdp_axes)
            elif e == TP:
                out.append(tp_axis)
            else:
                out.append(e)
        return P(*out)

    return jax.tree.map(fix, tree, is_leaf=lambda s: isinstance(s, P))
