"""Production mesh + sharding-rule resolution.

Mesh: (data=16, model=16) = 256 chips/pod; multi-pod adds a leading pod=2
axis (512 chips). Defined as FUNCTIONS — importing this module never touches
jax device state (required: only dryrun.py forces 512 host devices).

Sharding rules (DESIGN.md §6):
  train  — FSDP: weights/optimizer shard over (pod, data) x model;
           activations batch->data(+pod), sequence->model (Megatron-SP at
           block boundaries), TP on projections/experts.
  serve  — TP only; weights additionally shard over data if the per-chip
           bf16 footprint exceeds the HBM budget (inference-FSDP, e.g.
           deepseek-v2).

Every placement is divisibility-checked against the mesh: a dim that does
not divide falls back to replication for that dim (never a compile error —
e.g. smollm's 9 heads never shard over model=16; its flattened QKV features
do).
"""
from __future__ import annotations

import math
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.compat import AxisType, make_mesh
from repro.models.modules import FSDP, TP
from repro.models.transformer import ActSpecs

HBM_BYTES = 16 * 1024**3          # TPU v5e: 16 GB
SERVE_WEIGHT_BUDGET = 9 * 1024**3  # leave headroom for caches/activations


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(
        shape, axes, axis_types=(AxisType.Auto,) * len(axes)
    )


def mesh_axes(mesh: Mesh) -> dict[str, Any]:
    multi = "pod" in mesh.axis_names
    dp = ("pod", "data") if multi else ("data",)
    return {
        "dp": dp,
        "tp": "model",
        "dp_size": int(np.prod([mesh.shape[a] for a in dp])),
        "tp_size": int(mesh.shape["model"]),
    }


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return int(mesh.shape[axes])
    return int(np.prod([mesh.shape[a] for a in axes]))


def _fit(spec_entry, dim: int, mesh: Mesh):
    """Keep a spec entry only if the dim divides the axis product."""
    if spec_entry is None:
        return None
    return spec_entry if dim % _axis_size(mesh, spec_entry) == 0 else None


def _resolve_leaf_spec(spec: P, shape, mesh, fsdp_axes, tp_axis) -> P:
    out = []
    for i, e in enumerate(spec):
        if e == FSDP:
            e = fsdp_axes
        elif e == TP:
            e = tp_axis
        if e is not None and i < len(shape):
            e = _fit(e, shape[i], mesh)
        out.append(e)
    return P(*out)


def resolve_param_specs(spec_tree, shape_tree, mesh, *, mode: str,
                        param_bytes: int = 0):
    """Map FSDP/TP placeholders to mesh axes with divisibility fallback."""
    ax = mesh_axes(mesh)
    if mode == "train":
        fsdp: Any = ax["dp"] if len(ax["dp"]) > 1 else ax["dp"][0]
    else:
        # inference-FSDP only when TP-sharded weights would blow HBM
        per_chip = param_bytes / ax["tp_size"]
        fsdp = (
            (ax["dp"] if len(ax["dp"]) > 1 else ax["dp"][0])
            if per_chip > SERVE_WEIGHT_BUDGET
            else None
        )

    def fix(spec, shape):
        return _resolve_leaf_spec(spec, shape.shape, mesh, fsdp, ax["tp"])

    return jax.tree.map(
        fix, spec_tree, shape_tree, is_leaf=lambda s: isinstance(s, P)
    )


def act_specs(mesh: Mesh, *, seq_len: int, batch: int, mode: str,
              d_ff: int = 0) -> ActSpecs:
    ax = mesh_axes(mesh)
    dp = ax["dp"] if len(ax["dp"]) > 1 else ax["dp"][0]
    bdim = dp if batch % ax["dp_size"] == 0 else None
    # sequence-parallel residual stream in train (bounds the remat carry)
    sp = (
        ax["tp"]
        if mode == "train" and seq_len % ax["tp_size"] == 0
        else None
    )
    # MLP schedule (§Perf iter 3): Megatron-TP moves ~2·T_full·d activation
    # bytes/layer; ZeRO-3-style weight gathering moves ~3·d·ff. Choose dp
    # when the token side dominates (full-seq tokens per data shard).
    t_full = (batch // ax["dp_size"] if bdim else batch) * seq_len
    mlp_dp = d_ff > 0 and t_full > 1.5 * d_ff
    return ActSpecs(
        hid=P(bdim, sp, None),
        feat=P(bdim, None, ax["tp"]),
        exp=P(ax["tp"], bdim, None),
        logits=P(bdim, None, ax["tp"]),
        mesh=mesh,
        dp=dp,
        tp=ax["tp"],
        mlp_dp=mlp_dp,
    )


def batch_specs(batch_struct, mesh: Mesh) -> Any:
    """tokens/labels (B, S) -> P(dp, None); embeddings (B, S, d) likewise."""
    ax = mesh_axes(mesh)
    dp = ax["dp"] if len(ax["dp"]) > 1 else ax["dp"][0]

    def fix(x):
        bdim = dp if x.shape and x.shape[0] % ax["dp_size"] == 0 else None
        return P(*([bdim] + [None] * (len(x.shape) - 1)))

    return jax.tree.map(fix, batch_struct)


def cache_specs(cache_struct, mesh: Mesh) -> Any:
    """Stacked caches (L, B, ..., D_last): batch->dp, innermost divisible of
    the last two dims -> model, rest replicated."""
    ax = mesh_axes(mesh)
    dp = ax["dp"] if len(ax["dp"]) > 1 else ax["dp"][0]
    tp = ax["tp"]
    tp_n = ax["tp_size"]

    def fix(x):
        nd = len(x.shape)
        if nd <= 1:
            return P()
        spec = [None] * nd
        # batch axis: stacked caches have it at 1, unstacked at 0
        for b_ax in (1, 0):
            if b_ax < nd - 1 and x.shape[b_ax] % ax["dp_size"] == 0 and \
                    x.shape[b_ax] > 1:
                spec[b_ax] = dp
                break
        if x.shape[-1] % tp_n == 0:
            spec[-1] = tp
        elif nd >= 2 and x.shape[-2] % tp_n == 0 and spec[nd - 2] is None:
            spec[-2] = tp
        return P(*spec)

    return jax.tree.map(fix, cache_struct)


def named(mesh: Mesh, spec_tree) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda s: isinstance(s, P),
    )


def resolve_shardings(cfg, shape_cfg, mesh: Mesh):
    """One-stop: (param specs fn, act specs, batch/cache spec fns) per cell."""
    return {
        "act": act_specs(
            mesh, seq_len=shape_cfg.seq_len, batch=shape_cfg.global_batch,
            mode=shape_cfg.mode, d_ff=cfg.d_ff,
        ),
        "axes": mesh_axes(mesh),
    }
