from .mesh import (
    HBM_BYTES,
    make_production_mesh,
    mesh_axes,
    resolve_shardings,
)

__all__ = ["make_production_mesh", "mesh_axes", "resolve_shardings", "HBM_BYTES"]
