"""The canonical 'Cambridge' synthetic data set (Griffiths & Ghahramani 2011).

Four fixed binary 6x6 base images; each observation activates each feature
independently with probability 1/2 and adds isotropic Gaussian noise:

    X = Z A_true + eps,  eps ~ N(0, sigma_n^2),  X in R^{N x 36}.

The paper evaluates on the 1000 x 36 instance of this set.
"""
from __future__ import annotations

import numpy as np

# 4 features, each a 6x6 binary image (flattened to 36)
_F1 = np.array([
    [1, 1, 1, 0, 0, 0],
    [1, 0, 1, 0, 0, 0],
    [1, 1, 1, 0, 0, 0],
    [0, 0, 0, 0, 0, 0],
    [0, 0, 0, 0, 0, 0],
    [0, 0, 0, 0, 0, 0],
])
_F2 = np.array([
    [0, 0, 0, 1, 1, 1],
    [0, 0, 0, 1, 1, 0],
    [0, 0, 0, 1, 0, 0],
    [0, 0, 0, 0, 0, 0],
    [0, 0, 0, 0, 0, 0],
    [0, 0, 0, 0, 0, 0],
])
_F3 = np.array([
    [0, 0, 0, 0, 0, 0],
    [0, 0, 0, 0, 0, 0],
    [0, 0, 0, 0, 0, 0],
    [1, 0, 0, 0, 0, 0],
    [1, 1, 0, 0, 0, 0],
    [1, 1, 1, 0, 0, 0],
])
_F4 = np.array([
    [0, 0, 0, 0, 0, 0],
    [0, 0, 0, 0, 0, 0],
    [0, 0, 0, 0, 0, 0],
    [0, 0, 0, 0, 1, 1],
    [0, 0, 0, 1, 1, 1],
    [0, 0, 0, 0, 1, 1],
])

CAMBRIDGE_FEATURES = np.stack(
    [f.reshape(-1) for f in (_F1, _F2, _F3, _F4)]
).astype(np.float32)  # (4, 36)


def cambridge_data(
    N: int = 1000,
    sigma_n: float = 0.5,
    seed: int = 0,
    p_feature: float = 0.5,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Returns (X (N,36), Z_true (N,4), A_true (4,36))."""
    rng = np.random.default_rng(seed)
    Z = (rng.random((N, 4)) < p_feature).astype(np.float32)
    # guarantee no all-zero rows dominate tiny sets (match G&G: rows may be 0)
    X = Z @ CAMBRIDGE_FEATURES + sigma_n * rng.standard_normal((N, 36)).astype(
        np.float32
    )
    return X.astype(np.float32), Z, CAMBRIDGE_FEATURES.copy()
