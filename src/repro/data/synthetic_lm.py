"""Deterministic synthetic token pipeline for the LM substrate.

A cheap Zipf-ish Markov stream: reproducible across hosts (pure function of
(seed, step, shard)), infinite, no files — what the framework's data layer
feeds trainers in lieu of a tokenized corpus. Shard-aware: each data shard
draws a disjoint slice of the stream, the contract a real distributed loader
must satisfy.
"""
from __future__ import annotations

import numpy as np


class SyntheticLM:
    def __init__(self, vocab: int, seq_len: int, global_batch: int,
                 seed: int = 0, n_shards: int = 1):
        self.vocab = vocab
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.seed = seed
        self.n_shards = n_shards
        assert global_batch % n_shards == 0

    def batch(self, step: int, shard: int = 0) -> dict[str, np.ndarray]:
        b = self.global_batch // self.n_shards
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 97 + shard
        )
        # Zipf marginals + short-range repetition structure (so loss can fall)
        ranks = rng.zipf(1.3, size=(b, self.seq_len)).astype(np.int64)
        toks = np.minimum(ranks, self.vocab - 1)
        # inject copy structure: second half repeats first half shifted
        half = self.seq_len // 2
        toks[:, half:half * 2] = toks[:, :half]
        return {"tokens": toks.astype(np.int32)}
