"""Host-side observation sharding — the paper's 'divide X and Z along the
observation axis across P processors'."""
from __future__ import annotations

import numpy as np


def train_eval_split(X: np.ndarray, eval_frac: float = 0.1, seed: int = 0):
    """Deterministic held-out split (paper evaluates joint lik on held-out)."""
    rng = np.random.default_rng(seed)
    N = X.shape[0]
    perm = rng.permutation(N)
    n_eval = int(round(N * eval_frac))
    return X[perm[n_eval:]], X[perm[:n_eval]]


def shard_rows(X: np.ndarray, P: int) -> np.ndarray:
    """(N, D) -> (P, N_p, D), padding the tail by repeating the last row.

    Padding rows are real observations duplicated; for MCMC this perturbs the
    target slightly, so we instead TRIM to a multiple of P (exactness first).
    """
    N = X.shape[0]
    N_trim = (N // P) * P
    return X[:N_trim].reshape(P, N_trim // P, *X.shape[1:])


def unshard_rows(Xs: np.ndarray) -> np.ndarray:
    return Xs.reshape(-1, *Xs.shape[2:])
