from .cambridge import cambridge_data, CAMBRIDGE_FEATURES
from .sharding import shard_rows, unshard_rows, train_eval_split

__all__ = [
    "cambridge_data",
    "CAMBRIDGE_FEATURES",
    "shard_rows",
    "unshard_rows",
    "train_eval_split",
]
