"""Fault-tolerant MCMC driver: run loop, checkpoint/restart, elastic
re-sharding, capacity growth, diagnostics — over a ``Sampler`` built by
``build_sampler`` (DESIGN.md §13).

Large-scale runnability contract (DESIGN.md §10):

* every ``ckpt_every`` iterations the FULL sampler state (global params +
  gathered Z + tail buffers + RNG key) is written atomically; a restart
  resumes bitwise-identically (the state carries its own key).
* checkpoints store Z in *global* (unsharded) layout, so a restart may use a
  DIFFERENT shard count P — elastic scaling across restarts. Re-sharding is
  a pure reshape of the observation axis.
* capacity growth: if feature-slot overflow is detected (gs.overflow), the
  driver checkpoints and raises; a restart with a larger ``K_max`` pads the
  checkpointed feature axis with empty slots and resumes — growth is a
  restart event, never a silent truncation. The inverse is also a restart
  event: restoring under a SMALLER ``K_max`` compacts live features (plus
  the lowest free slots, the packed-carry block rule — DESIGN.md §14)
  into the new capacity, so shrink-after-burn-in bounds every K_max-sized
  buffer again; it refuses loudly if the live set does not fit.
* straggler policy on real meshes: synchronous collectives absorb jitter; a
  dead pod is a restart from the latest checkpoint (same path as above). The
  paper's L sub-iterations amortize sync cost; ``stale_sync`` (bounded
  staleness: that many sync-free sub-iteration passes are interleaved
  before each full iteration) exists as an opt-in knob and is non-exact.

Parallelism layout (DESIGN.md §13): the driver takes a ``SamplerSpec``
(or a legacy ``DriverConfig``, kept as a thin shim that maps the old
scattered kwargs onto a spec) and builds ONE ``Sampler`` whose
``chains`` x ``data`` axes replace the old backend enum:

* ``driver="vmap"``       — chains "none"  x data "vmap"
* ``driver="multichain"`` — chains "vmap"  x data "vmap" (R-hat/ESS/MCSE
  over the per-iteration trace in eval records)
* ``driver="shardmap"``   — chains "none"  x data "shardmap"
* ``driver="mesh"``       — chains "mesh"  x data "shardmap": C chains x
  P data shards on a 2-D ``("chains", "data")`` mesh — the composed path
  (multichain diagnostics AND real data collectives), runnable on CPU
  via ``--xla_force_host_platform_device_count``.

State crosses the driver boundary in the canonical (C?, P, N_p, K)
layout, so checkpoints are interchangeable across all layouts with the
same chain count.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

import os

from repro.checkpoint import restore, save_pytree
from repro.core.ibp import IBPHypers, SamplerSpec, build_sampler
from repro.core.ibp import convergence
from repro.core.ibp.api import DRIVERS
from repro.core.ibp.collapsed import (
    DEFAULT_REFRESH as DEFAULT_CHOL_REFRESH,
)
from repro.core.ibp.hybrid import HybridGlobal, HybridShard
from repro.core.ibp.predict import (
    BankBuilder,
    SampleBank,
    heldout_joint_loglik,
    train_joint_loglik,
)

BACKENDS = tuple(DRIVERS)  # historical name for the driver grid


@dataclasses.dataclass
class DriverConfig:
    """DEPRECATED shim: the old scattered-kwarg construction surface.

    Maps 1:1 onto ``SamplerSpec`` via ``to_spec()`` (see the migration
    table in DESIGN.md §13). New code should construct a ``SamplerSpec``
    directly — the spec validates every knob combination loudly and
    expresses parallelism as composable ``chains`` x ``data`` axes
    instead of the ``driver`` enum.
    """

    P: int = 4
    K_max: int = 32
    K_tail: int = 8
    L: int = 5
    n_iters: int = 1000
    ckpt_every: int = 100
    ckpt_dir: str = "artifacts/ckpt/ibp"
    eval_every: int = 20
    seed: int = 0
    alpha: float = 3.0
    sigma_x: float = 1.0
    sigma_a: float = 1.0
    K_init: int = 4
    backend: str = "jnp"       # "jnp" | "pallas" for the uncollapsed sweep
    stale_sync: int = 0        # >0 = bounded staleness (non-exact)
    driver: str = "vmap"       # "vmap"|"multichain"|"shardmap"|"mesh"
    n_chains: int = 1          # chain count (multichain / mesh)
    sync: str = "staged"       # "staged" | "fused" master sync (collective)
    overflow_every: int = 8    # overflow-detection cadence (host sync)
    k_tail_grow: int = 0       # adaptive K_tail: max tail doublings (0=off)
    collapsed_backend: str = "fast"  # "ref" | "fast" | "pallas" tail step
    chol_refresh: int = DEFAULT_CHOL_REFRESH  # "fast"/"pallas" cadence
    k_live_buckets: str = "on"  # occupancy-adaptive packing (DESIGN.md §14)
    harvest_every: int = 0     # SampleBank harvest cadence (0 = off, §15)
    harvest_burn: float = 0.5  # burn-in fraction before harvesting
    bank_path: str = ""        # bank npz ("" = <ckpt_dir>/bank.npz)

    def to_spec(self) -> SamplerSpec:
        if self.driver not in DRIVERS:
            raise ValueError(f"driver={self.driver!r} not in {BACKENDS}")
        chains, data = DRIVERS[self.driver]
        return SamplerSpec(
            P=self.P, K_max=self.K_max, K_tail=self.K_tail,
            K_init=self.K_init, alpha=self.alpha, sigma_x=self.sigma_x,
            sigma_a=self.sigma_a, L=self.L, backend=self.backend,
            collapsed_backend=self.collapsed_backend,
            chol_refresh=self.chol_refresh,
            k_live_buckets=self.k_live_buckets,
            chains=chains, data=data, n_chains=self.n_chains,
            sync=self.sync, stale_sync=self.stale_sync,
            n_iters=self.n_iters, eval_every=self.eval_every,
            ckpt_every=self.ckpt_every, ckpt_dir=self.ckpt_dir,
            overflow_every=self.overflow_every,
            k_tail_grow=self.k_tail_grow, seed=self.seed,
            harvest_every=self.harvest_every,
            harvest_burn=self.harvest_burn, bank_path=self.bank_path,
        )


def as_spec(cfg: DriverConfig | SamplerSpec) -> SamplerSpec:
    """Normalize either config surface to a validated SamplerSpec."""
    return cfg.to_spec() if isinstance(cfg, DriverConfig) else cfg


def _pad_trailing(x: jax.Array, axis: int, n: int) -> jax.Array:
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, n)
    return jnp.pad(x, pads)


class MCMCDriver:
    """Runs a built Sampler with checkpoint/restart + elastic P."""

    def __init__(self, X: np.ndarray, cfg: DriverConfig | SamplerSpec,
                 hyp: IBPHypers | None = None, X_eval: np.ndarray | None = None):
        spec = as_spec(cfg)
        self.spec = spec
        self.cfg = spec  # back-compat alias: run knobs live on the spec
        self.hyp = hyp or IBPHypers()
        self.sampler = build_sampler(spec, self.hyp, X)
        self.X_global = self.sampler.X_global
        self.N = self.sampler.N
        self.X_eval = None if X_eval is None else jnp.asarray(X_eval)
        self.history: list[dict[str, float]] = []
        # per-iteration scalar traces, one column per chain — the raw
        # material for split-R-hat / ESS in eval records
        self.trace: dict[str, list[np.ndarray]] = {"sigma_x": [], "K": []}
        self._chain_axis = self.sampler.chain_axis
        # posterior-predictive harvest (DESIGN.md §15): the builder
        # accumulates post-burn-in samples host-side at harvest cadence;
        # the built bank is persisted NEXT TO the checkpoints but is a
        # separate, self-describing artifact — serving restores it with
        # no sampler state at all (core/ibp/predict.py)
        self.bank_builder = (BankBuilder(spec.K_max)
                             if spec.harvest_every > 0 else None)
        self._bank: SampleBank | None = None
        # adaptive K_tail (DESIGN.md §12): doublings performed so far and
        # the tail_sat watermark at the last checkpoint boundary — growth
        # fires only on NEW saturation since that boundary
        self._tail_growths = 0
        self._sat_mark = 0

    # ---- state <-> checkpoint layout (global Z for elastic resharding) ----
    def _to_ckpt(self, gs: HybridGlobal, ss: HybridShard) -> dict:
        # tail buffers are NOT serialized: checkpoints are written post-sync,
        # where tails are always cleared — _from_ckpt rebuilds them empty at
        # the configured K_tail (which a restart may therefore resize)
        *lead, P, N_p, K = ss.Z.shape
        return {
            "gs": gs,
            "Z_global": ss.Z.reshape(*lead, P * N_p, K),
            "meta": {"it": gs.it},
        }

    def _shrink_features(self, gs: HybridGlobal, Zg, K_new: int):
        """Shrink restart: compact a checkpoint's feature axis into a
        SMALLER configured K_max (the capacity-growth path's inverse,
        DESIGN.md §14). The kept columns are every live feature plus the
        lowest-index free slots — the same block rule as the packed
        collapsed carry — so the posterior state is untouched and only
        dead slots are relabeled. After burn-in settles K⁺ well below a
        grown K_max, this bounds every K_max-sized buffer (and the
        packed scan's bucket ladder) again. Refuses loudly when the live
        features do not fit: shrinking never silently truncates state.
        Chain-batched checkpoints compact per chain (each chain has its
        own live set).
        """
        act = np.asarray(gs.active)
        Zg_h, A_h, pi_h = np.asarray(Zg), np.asarray(gs.A), np.asarray(gs.pi)
        lead = act.shape[:-1]  # () chainless, (C,) chainful
        act2 = act.reshape(-1, act.shape[-1])
        cols = []
        for c, a_row in enumerate(act2):
            live = np.flatnonzero(a_row > 0.5)
            if live.size > K_new:
                who = f"chain {c} of the checkpoint" if lead else \
                    "the checkpoint"
                raise ValueError(
                    f"cannot shrink to K_max={K_new}: {who} carries "
                    f"{live.size} live features; restart with "
                    f"K_max >= {live.size}"
                )
            free = np.flatnonzero(a_row <= 0.5)
            cols.append(np.sort(np.concatenate(
                [live, free[:K_new - live.size]])))
        if lead:
            C = len(cols)
            Zg_h = np.stack([Zg_h[c][..., cols[c]] for c in range(C)])
            A_h = np.stack([A_h[c][cols[c]] for c in range(C)])
            pi_h = np.stack([pi_h[c][cols[c]] for c in range(C)])
            act_h = np.stack([act2[c][cols[c]] for c in range(C)])
        else:
            Zg_h = Zg_h[..., cols[0]]
            A_h, pi_h, act_h = A_h[cols[0]], pi_h[cols[0]], act[cols[0]]
        gs = dataclasses.replace(
            gs, A=jnp.asarray(A_h), pi=jnp.asarray(pi_h),
            active=jnp.asarray(act_h),
        )
        return gs, jnp.asarray(Zg_h)

    def _from_ckpt(self, blob: dict) -> tuple[HybridGlobal, HybridShard]:
        spec = self.spec
        gs: HybridGlobal = blob["gs"]
        Zg = blob["Z_global"]
        K_ck = Zg.shape[-1]
        if K_ck > spec.K_max:
            # shrink restart: compact live features into the smaller
            # capacity instead of refusing (growth's inverse)
            gs, Zg = self._shrink_features(gs, Zg, spec.K_max)
        if K_ck < spec.K_max:
            # capacity-growth restart: pad the feature axis with empty slots
            grow = spec.K_max - K_ck
            Zg = _pad_trailing(Zg, -1, grow)
            gs = dataclasses.replace(
                gs,
                A=_pad_trailing(gs.A, -2, grow),
                pi=_pad_trailing(gs.pi, -1, grow),
                active=_pad_trailing(gs.active, -1, grow),
                overflow=jnp.zeros_like(gs.overflow),
            )
        *lead, N, K = Zg.shape
        # elastic P is a reshape of the observation axis — the checkpoint's
        # N must survive the new config's truncation and divide by P, else
        # fail with a message instead of a deep reshape/broadcast error
        if N != self.N:
            raise ValueError(
                f"checkpoint has N={N} observations but this driver "
                f"truncated the data to N={self.N} (P={spec.P}); pick a P "
                f"that keeps N={N}"
            )
        # chain-axis compatibility is checked loudly: a single-chain
        # checkpoint must not silently restore under a chain-batched
        # template (or vice versa), and the chain count is part of the
        # state — n_chains cannot change across a restart (the layout of
        # the chain axis CAN: multichain <-> mesh restores are legal)
        if self._chain_axis:
            if not lead or lead[0] != spec.n_chains:
                raise ValueError(
                    f"checkpoint chain axis {lead or 'absent'} does not "
                    f"match configured n_chains={spec.n_chains}"
                )
        elif lead:
            raise ValueError(
                f"checkpoint carries a chain axis {lead}; restore it with "
                f"driver='multichain'/'mesh' and n_chains={lead[0]}"
            )
        P = spec.P
        # tails are cleared at every master sync, and checkpoints are only
        # written post-sync — so tail buffers are rebuilt EMPTY at the
        # CONFIGURED K_tail (a restart may widen/narrow tail exploration;
        # the checkpoint's tail width does not pin it)
        ss = HybridShard(
            Z=Zg.reshape(*lead, P, N // P, K),
            Z_tail=jnp.zeros((*lead, P, N // P, spec.K_tail), Zg.dtype),
            tail_active=jnp.zeros((*lead, P, spec.K_tail), Zg.dtype),
        )
        return gs, ss

    def _template(self):
        gs, st = self.sampler.init()
        return self._to_ckpt(gs, self.sampler.to_canonical(st))

    # ---- posterior-predictive harvest (DESIGN.md §15) ---------------------
    @property
    def bank_path(self) -> str:
        return self.spec.bank_path or os.path.join(self.spec.ckpt_dir,
                                                   "bank.npz")

    @property
    def bank(self) -> SampleBank | None:
        """The harvested ensemble as a built SampleBank (None before the
        first harvest). Rebuilt lazily when new samples arrived."""
        b = self.bank_builder
        if b is None or len(b) == 0:
            return self._bank
        if self._bank is None or self._bank.S != len(b):
            self._bank = b.build()
        return self._bank

    def save_bank(self) -> str | None:
        """Build + persist the bank (npz, restorable with no sampler
        state). Returns the path, or None if nothing was harvested."""
        bank = self.bank
        if bank is None:
            return None
        return bank.save(self.bank_path)

    # ---- adaptive K_tail (DESIGN.md §12) ----------------------------------
    def _maybe_grow_tail(self, gs: HybridGlobal, ss: HybridShard):
        """Double K_tail when NEW tail saturation accrued since the last
        checkpoint boundary (capacity-vetoed accepted births on p' —
        gs.tail_sat), bounded by ``k_tail_grow`` doublings and the K_max
        ceiling. Runs exactly at a post-sync checkpoint boundary: tails
        are always cleared there, so the sampler is rebuilt in-process
        with EMPTY tail buffers at the new width and the posterior state
        is untouched — growth is a pure widening of future exploration,
        not a restart. The counter resets so the next decision sees only
        post-growth saturation. Returns (gs, ss, grew)."""
        spec = self.spec
        sat = int(jnp.max(gs.tail_sat))
        grew = False
        if (self._tail_growths < spec.k_tail_grow
                and spec.K_tail < spec.K_max and sat > self._sat_mark):
            new_tail = min(2 * spec.K_tail, spec.K_max)
            spec = spec.replace(K_tail=new_tail)
            self.spec = self.cfg = spec
            self.sampler = build_sampler(spec, self.hyp, self.X_global)
            *lead, P, N_p, _ = ss.Z.shape
            ss = HybridShard(
                Z=ss.Z,
                Z_tail=jnp.zeros((*lead, P, N_p, new_tail), ss.Z.dtype),
                tail_active=jnp.zeros((*lead, P, new_tail), ss.Z.dtype),
            )
            gs = dataclasses.replace(gs,
                                     tail_sat=jnp.zeros_like(gs.tail_sat))
            self._tail_growths += 1
            grew = True
        self._sat_mark = int(jnp.max(gs.tail_sat))
        return gs, ss, grew

    # ---- main loop --------------------------------------------------------
    def run(self, n_iters: int | None = None,
            on_eval: Callable[[dict], None] | None = None,
            crash_at: int | None = None):
        """Main loop. ``crash_at`` raises mid-run (for restart tests)."""
        spec = self.spec
        sampler = self.sampler
        n_iters = n_iters or spec.n_iters
        restored = restore(spec.ckpt_dir, self._template())
        if restored is not None:
            blob, start = restored[0], int(restored[1])
            gs, ss = self._from_ckpt(blob)
            st = sampler.from_canonical(ss)  # native, device-resident
            # a restart continues the harvest from the persisted bank
            # instead of overwriting it with a shorter ensemble...
            if (self.bank_builder is not None
                    and len(self.bank_builder) == 0
                    and os.path.exists(self.bank_path)):
                self.bank_builder.extend_from(SampleBank.load(self.bank_path))
            # ...and reconciles it with the REWIND: iterations past the
            # restored step re-run and re-harvest, so samples beyond it
            # are dropped first — whether they came from the persisted
            # bank (bank saved after the restored checkpoint) or from
            # this same driver object's interrupted run() — keeping
            # every draw exactly once in the ensemble
            if self.bank_builder is not None:
                self.bank_builder.prune_after(start)
                self._bank = None
        else:
            start = 0
            gs, st = sampler.init(jax.random.key(spec.seed))
            # fresh start = iteration 0: an interrupted same-object run()
            # that never checkpointed must not leak its harvests into
            # this rerun (the iterations re-run and re-harvest)
            if self.bank_builder is not None:
                self.bank_builder.prune_after(0)
                self._bank = None

        t0 = time.time()
        for it in range(start, n_iters):
            if crash_at is not None and it == crash_at:
                raise RuntimeError(f"injected crash at iteration {it}")
            for _ in range(spec.stale_sync):
                gs, st = sampler.stale(gs, st)
            gs, st = sampler.step(gs, st)
            self._record_trace(gs)
            last = it == n_iters - 1
            # harvest the post-sync posterior draw into the sample bank
            # (host transfer of the K_max-sized params only — never Z)
            if (self.bank_builder is not None
                    and (it + 1) > int(spec.harvest_burn * n_iters)
                    and (it + 1) % spec.harvest_every == 0):
                self.bank_builder.add_state(gs, it=it + 1)
            need_eval = (it + 1) % spec.eval_every == 0 or last
            need_ckpt = (it + 1) % spec.ckpt_every == 0 or last
            # pulling gs.overflow blocks the host on the iteration's whole
            # computation, so check at a bounded cadence, not every step —
            # detection delay is <= overflow_every iterations (DESIGN.md §10)
            overflowed = (
                need_eval or need_ckpt
                or (it + 1) % spec.overflow_every == 0
            ) and int(jnp.max(gs.overflow)) > 0
            if need_eval or need_ckpt or overflowed:
                # canonical layout is materialized at cadence only — the
                # hot loop never leaves the layout's native state
                ss = sampler.to_canonical(st)
            if need_eval:
                rec = self.evaluate(gs, ss, it + 1, time.time() - t0)
                self.history.append(rec)
                if on_eval:
                    on_eval(rec)
            if need_ckpt:
                # the bank rides the checkpoint cadence for durability
                # (own self-describing file), and is written FIRST: a
                # crash between the two writes then rewinds to an older
                # checkpoint whose re-run re-harvests — prune_after on
                # restore reconciles — whereas checkpoint-first would
                # resume PAST unsaved harvests and lose them forever
                if self.bank_builder is not None and len(self.bank_builder):
                    self.save_bank()
                save_pytree(spec.ckpt_dir, self._to_ckpt(gs, ss), it + 1)
                # adaptive K_tail rides the checkpoint boundary (the one
                # place tails are provably empty): saturation since the
                # last boundary doubles the tail width in-process — the
                # just-written checkpoint stays valid (tails are not
                # serialized; a restart re-grows if saturation returns)
                if spec.k_tail_grow > 0 and not last and not overflowed:
                    gs, ss, grew = self._maybe_grow_tail(gs, ss)
                    if grew:
                        spec = self.spec
                        sampler = self.sampler
                        st = sampler.from_canonical(ss)
            if overflowed:
                # capacity growth: checkpoint + restart with larger K_max.
                # the bank is saved too (bank-first, as above) — the
                # restart resumes AFTER this iteration, so harvests since
                # the last cadence save would otherwise be dropped
                if not need_ckpt:
                    if (self.bank_builder is not None
                            and len(self.bank_builder)):
                        self.save_bank()
                    save_pytree(spec.ckpt_dir, self._to_ckpt(gs, ss), it + 1)
                raise RuntimeError(
                    f"K_max={spec.K_max} overflow at it={it}; restart with "
                    f"2x K_max"
                )
        return gs, sampler.to_canonical(st)

    # ---- diagnostics ------------------------------------------------------
    def _record_trace(self, gs: HybridGlobal) -> None:
        # keep DEVICE arrays: np.asarray here would block on every
        # iteration's whole computation and kill async dispatch — the
        # host sync is deferred to diagnostics() (eval cadence)
        self.trace["sigma_x"].append(jnp.atleast_1d(gs.sigma_x))
        self.trace["K"].append(jnp.atleast_1d(jnp.sum(gs.active, axis=-1)))

    def diagnostics(self, burn_frac: float = 0.5) -> dict[str, float]:
        """split-R-hat / ESS / MCSE of the monitored scalars over the
        post-burn tail of the per-iteration trace (DESIGN.md §11).
        R-hat is NaN until the trace has enough post-burn draws."""
        out: dict[str, float] = {}
        for name, rows in self.trace.items():
            # convert each device row to host numpy ONCE, in place —
            # releases the device buffer and keeps repeat evals linear
            for i, r in enumerate(rows):
                if not isinstance(r, np.ndarray):
                    rows[i] = np.asarray(r, np.float64)
            if len(rows) < 8:
                continue
            arr = np.stack(rows, axis=1)               # (C, T)
            tail = arr[:, int(burn_frac * arr.shape[1]):]
            s = convergence.summarize(tail, name)
            for k in ("rhat", "ess", "mcse"):
                out[f"{name}_{k}"] = s[f"{name}_{k}"]
        return out

    def evaluate(self, gs: HybridGlobal, ss: HybridShard, it: int,
                 elapsed: float) -> dict[str, Any]:
        X = jnp.asarray(self.X_global)
        if self._chain_axis:
            C = ss.Z.shape[0]
            Z = ss.Z.reshape(C, self.N, -1)
            lls = jax.vmap(
                train_joint_loglik, in_axes=(None, 0, 0, 0, 0, 0)
            )(X, Z, gs.A, gs.pi, gs.active, gs.sigma_x)
            Ks = np.asarray(jnp.sum(gs.active, axis=-1))
            rec: dict[str, Any] = {
                "it": it,
                "t": elapsed,
                "K": float(Ks.mean()),
                "K_chains": [int(k) for k in Ks],
                "alpha": float(jnp.mean(gs.alpha)),
                "sigma_x": float(jnp.mean(gs.sigma_x)),
                "sigma_x_chains": [float(s) for s in np.asarray(gs.sigma_x)],
                "joint_ll_train": float(jnp.mean(lls)),
                "joint_ll_train_chains": [float(l) for l in np.asarray(lls)],
                "K_tail": int(self.spec.K_tail),
                "tail_sat": int(jnp.max(gs.tail_sat)),
                "tail_sat_chains": [int(s)
                                    for s in np.asarray(gs.tail_sat)],
            }
            if self.X_eval is not None:
                ev = jax.vmap(
                    lambda A, pi, act, sx, k: heldout_joint_loglik(
                        self.X_eval, A, pi, act, sx,
                        jax.random.fold_in(k, 999),
                    )
                )(gs.A, gs.pi, gs.active, gs.sigma_x, gs.key)
                rec["joint_ll_eval"] = float(jnp.mean(ev))
        else:
            Z = ss.Z.reshape(self.N, -1)
            rec = {
                "it": it,
                "t": elapsed,
                "K": int(jnp.sum(gs.active)),
                "alpha": float(gs.alpha),
                "sigma_x": float(gs.sigma_x),
                "joint_ll_train": float(train_joint_loglik(
                    X, Z, gs.A, gs.pi, gs.active, gs.sigma_x
                )),
                "K_tail": int(self.spec.K_tail),
                "tail_sat": int(gs.tail_sat),
            }
            if self.X_eval is not None:
                rec["joint_ll_eval"] = float(heldout_joint_loglik(
                    self.X_eval, gs.A, gs.pi, gs.active, gs.sigma_x,
                    jax.random.fold_in(gs.key, 999),
                ))
        rec.update(self.diagnostics())
        return rec
