"""Fault-tolerant MCMC driver: checkpoint/restart, elastic re-sharding,
straggler policy.

Large-scale runnability contract (DESIGN.md §10):

* every ``ckpt_every`` iterations the FULL sampler state (global params +
  gathered Z + tail buffers + RNG key) is written atomically; a restart
  resumes bitwise-identically (the state carries its own key).
* checkpoints store Z in *global* (unsharded) layout, so a restart may use a
  DIFFERENT shard count P — elastic scaling across restarts. Re-sharding is
  a pure reshape of the observation axis.
* capacity growth: if feature-slot overflow is detected (gs.overflow), the
  driver checkpoints, doubles K_max, and restarts in-process — growth is a
  restart event, never a silent truncation.
* straggler policy on real meshes: synchronous collectives absorb jitter; a
  dead pod is a restart from the latest checkpoint (same path as above). The
  paper's L sub-iterations amortize sync cost; ``stale_sync`` (bounded
  staleness) exists as an opt-in knob and is marked non-exact.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore, save_pytree
from repro.core.ibp import IBPHypers, hybrid_iteration_vmap, init_hybrid
from repro.core.ibp.hybrid import HybridGlobal, HybridShard
from repro.core.ibp.diagnostics import heldout_joint_loglik, train_joint_loglik


@dataclasses.dataclass
class DriverConfig:
    P: int = 4
    K_max: int = 32
    K_tail: int = 8
    L: int = 5
    n_iters: int = 1000
    ckpt_every: int = 100
    ckpt_dir: str = "artifacts/ckpt/ibp"
    eval_every: int = 20
    seed: int = 0
    alpha: float = 3.0
    sigma_x: float = 1.0
    sigma_a: float = 1.0
    K_init: int = 4
    backend: str = "jnp"       # "jnp" | "pallas" for the uncollapsed sweep
    stale_sync: int = 0        # >0 = bounded staleness (non-exact, off by default)


class MCMCDriver:
    """Runs the hybrid sampler with checkpoint/restart + elastic P."""

    def __init__(self, X: np.ndarray, cfg: DriverConfig,
                 hyp: IBPHypers | None = None, X_eval: np.ndarray | None = None):
        self.cfg = cfg
        self.hyp = hyp or IBPHypers()
        N = (X.shape[0] // cfg.P) * cfg.P
        self.X_global = np.asarray(X[:N], np.float32)
        self.X_eval = None if X_eval is None else jnp.asarray(X_eval)
        self.Xs = jnp.asarray(
            self.X_global.reshape(cfg.P, N // cfg.P, X.shape[1])
        )
        self.N = N
        self.history: list[dict[str, float]] = []

    # ---- state <-> checkpoint layout (global Z for elastic resharding)
    def _to_ckpt(self, gs: HybridGlobal, ss: HybridShard) -> dict:
        P, N_p, K = ss.Z.shape
        return {
            "gs": gs,
            "Z_global": ss.Z.reshape(P * N_p, K),
            "Z_tail_global": ss.Z_tail.reshape(P * N_p, ss.Z_tail.shape[2]),
            "tail_active": jnp.max(ss.tail_active, axis=0),
            "meta": {"it": gs.it},
        }

    def _from_ckpt(self, blob: dict) -> tuple[HybridGlobal, HybridShard]:
        P = self.cfg.P
        gs = blob["gs"]
        Zg = blob["Z_global"]
        Ztg = blob["Z_tail_global"]
        N, K = Zg.shape
        ss = HybridShard(
            Z=Zg.reshape(P, N // P, K),
            Z_tail=Ztg.reshape(P, N // P, Ztg.shape[1]),
            tail_active=jnp.tile(blob["tail_active"][None], (P, 1))
            * 0.0,  # tails are cleared at sync; safe to drop on reshard
        )
        return gs, ss

    def _template(self):
        gs, ss = init_hybrid(
            jax.random.key(self.cfg.seed), self.Xs, self.cfg.K_max,
            K_tail=self.cfg.K_tail, alpha=self.cfg.alpha,
            sigma_x=self.cfg.sigma_x, sigma_a=self.cfg.sigma_a,
            K_init=self.cfg.K_init,
        )
        return self._to_ckpt(gs, ss)

    def run(self, n_iters: int | None = None,
            on_eval: Callable[[dict], None] | None = None,
            crash_at: int | None = None):
        """Main loop. ``crash_at`` raises mid-run (for restart tests)."""
        cfg = self.cfg
        n_iters = n_iters or cfg.n_iters
        restored = restore(cfg.ckpt_dir, self._template())
        if restored is not None:
            blob, start = restored[0], int(restored[1])
            gs, ss = self._from_ckpt(blob)
        else:
            start = 0
            gs, ss = init_hybrid(
                jax.random.key(cfg.seed), self.Xs, cfg.K_max,
                K_tail=cfg.K_tail, alpha=cfg.alpha, sigma_x=cfg.sigma_x,
                sigma_a=cfg.sigma_a, K_init=cfg.K_init,
            )

        t0 = time.time()
        for it in range(start, n_iters):
            if crash_at is not None and it == crash_at:
                raise RuntimeError(f"injected crash at iteration {it}")
            gs, ss = hybrid_iteration_vmap(
                self.Xs, gs, ss, self.hyp, L=cfg.L, N_global=self.N,
                backend=cfg.backend,
            )
            if (it + 1) % cfg.eval_every == 0 or it == n_iters - 1:
                rec = self.evaluate(gs, ss, it + 1, time.time() - t0)
                self.history.append(rec)
                if on_eval:
                    on_eval(rec)
            if (it + 1) % cfg.ckpt_every == 0 or it == n_iters - 1:
                save_pytree(cfg.ckpt_dir, self._to_ckpt(gs, ss), it + 1)
            if int(gs.overflow) > 0:
                # capacity growth: checkpoint + restart with larger K_max
                save_pytree(cfg.ckpt_dir, self._to_ckpt(gs, ss), it + 1)
                raise RuntimeError(
                    f"K_max={cfg.K_max} overflow at it={it}; restart with 2x K_max"
                )
        return gs, ss

    def evaluate(self, gs: HybridGlobal, ss: HybridShard, it: int,
                 elapsed: float) -> dict[str, float]:
        Z = ss.Z.reshape(self.N, -1)
        ll_train = float(train_joint_loglik(
            jnp.asarray(self.X_global), Z, gs.A, gs.pi, gs.active, gs.sigma_x
        ))
        rec = {
            "it": it,
            "t": elapsed,
            "K": int(jnp.sum(gs.active)),
            "alpha": float(gs.alpha),
            "sigma_x": float(gs.sigma_x),
            "joint_ll_train": ll_train,
        }
        if self.X_eval is not None:
            rec["joint_ll_eval"] = float(heldout_joint_loglik(
                self.X_eval, gs.A, gs.pi, gs.active, gs.sigma_x,
                jax.random.fold_in(gs.key, 999),
            ))
        return rec
