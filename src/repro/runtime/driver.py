"""Fault-tolerant MCMC driver: backend selection, multi-chain inference,
checkpoint/restart, elastic re-sharding, capacity growth, diagnostics.

Large-scale runnability contract (DESIGN.md §10):

* every ``ckpt_every`` iterations the FULL sampler state (global params +
  gathered Z + tail buffers + RNG key) is written atomically; a restart
  resumes bitwise-identically (the state carries its own key).
* checkpoints store Z in *global* (unsharded) layout, so a restart may use a
  DIFFERENT shard count P — elastic scaling across restarts. Re-sharding is
  a pure reshape of the observation axis.
* capacity growth: if feature-slot overflow is detected (gs.overflow), the
  driver checkpoints and raises; a restart with a larger ``K_max`` pads the
  checkpointed feature axis with empty slots and resumes — growth is a
  restart event, never a silent truncation.
* straggler policy on real meshes: synchronous collectives absorb jitter; a
  dead pod is a restart from the latest checkpoint (same path as above). The
  paper's L sub-iterations amortize sync cost; ``stale_sync`` (bounded
  staleness: that many sync-free sub-iteration passes are interleaved
  before each full iteration) exists as an opt-in knob and is non-exact.

Backend selection (DESIGN.md §11): ``DriverConfig.driver`` picks how one
iteration is computed — the statistical kernel is identical in all three:

* ``"vmap"``       — P shards simulated by vmap on one device (default).
* ``"multichain"`` — C independent chains (``n_chains``) advanced in one
  jitted step via a chain axis vmapped over the full iteration; eval
  records report split-R-hat / ESS / MCSE over the per-iteration trace.
* ``"shardmap"``   — the production collective path over a ``(data,)``
  mesh of P devices (``sync`` selects the staged/fused master schedule).
  State crosses the driver boundary in the canonical (P, N_p, K) layout,
  so checkpoints are interchangeable across all backends.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore, save_pytree
from repro.core.ibp.collapsed import (
    COLLAPSED_BACKENDS,
    DEFAULT_REFRESH as DEFAULT_CHOL_REFRESH,
)
from repro.core.ibp import (
    IBPHypers,
    hybrid_iteration_multichain,
    hybrid_iteration_vmap,
    hybrid_stale_pass,
    init_hybrid,
    init_multichain,
    make_hybrid_iteration_shardmap,
    make_hybrid_stale_pass_shardmap,
)
from repro.core.ibp import convergence
from repro.core.ibp.hybrid import HybridGlobal, HybridShard
from repro.core.ibp.diagnostics import heldout_joint_loglik, train_joint_loglik

BACKENDS = ("vmap", "multichain", "shardmap")


@dataclasses.dataclass
class DriverConfig:
    P: int = 4
    K_max: int = 32
    K_tail: int = 8
    L: int = 5
    n_iters: int = 1000
    ckpt_every: int = 100
    ckpt_dir: str = "artifacts/ckpt/ibp"
    eval_every: int = 20
    seed: int = 0
    alpha: float = 3.0
    sigma_x: float = 1.0
    sigma_a: float = 1.0
    K_init: int = 4
    backend: str = "jnp"       # "jnp" | "pallas" for the uncollapsed sweep
    stale_sync: int = 0        # >0 = bounded staleness (non-exact, off by default)
    driver: str = "vmap"       # "vmap" | "multichain" | "shardmap"
    n_chains: int = 1          # chain count for driver="multichain"
    sync: str = "staged"       # "staged" | "fused" master sync (shardmap only)
    overflow_every: int = 8    # overflow-detection cadence (host sync each check)
    collapsed_backend: str = "ref"  # "ref" | "fast" | "pallas" tail row step
    chol_refresh: int = DEFAULT_CHOL_REFRESH  # "fast"/"pallas" refactor cadence


def _pad_trailing(x: jax.Array, axis: int, n: int) -> jax.Array:
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, n)
    return jnp.pad(x, pads)


class MCMCDriver:
    """Runs the hybrid sampler with checkpoint/restart + elastic P."""

    def __init__(self, X: np.ndarray, cfg: DriverConfig,
                 hyp: IBPHypers | None = None, X_eval: np.ndarray | None = None):
        if cfg.driver not in BACKENDS:
            raise ValueError(f"driver={cfg.driver!r} not in {BACKENDS}")
        if cfg.driver == "multichain" and cfg.n_chains < 1:
            raise ValueError("multichain driver needs n_chains >= 1")
        if cfg.driver != "multichain" and cfg.n_chains > 1:
            raise ValueError(
                f"n_chains={cfg.n_chains} has no effect with "
                f"driver={cfg.driver!r}; use driver='multichain'"
            )
        if cfg.sync not in ("staged", "fused"):
            raise ValueError(f"sync={cfg.sync!r} not in ('staged', 'fused')")
        if cfg.sync != "staged" and cfg.driver != "shardmap":
            raise ValueError(
                f"sync={cfg.sync!r} has no effect with "
                f"driver={cfg.driver!r}; use driver='shardmap'"
            )
        if cfg.collapsed_backend not in COLLAPSED_BACKENDS:
            raise ValueError(
                f"collapsed_backend={cfg.collapsed_backend!r} not in "
                f"{COLLAPSED_BACKENDS}"
            )
        if cfg.chol_refresh < 1:
            raise ValueError(f"chol_refresh={cfg.chol_refresh} must be >= 1")
        self.cfg = cfg
        self.hyp = hyp or IBPHypers()
        N = (X.shape[0] // cfg.P) * cfg.P
        self.X_global = np.asarray(X[:N], np.float32)
        self.X_eval = None if X_eval is None else jnp.asarray(X_eval)
        self.Xs = jnp.asarray(
            self.X_global.reshape(cfg.P, N // cfg.P, X.shape[1])
        )
        self.N = N
        self.history: list[dict[str, float]] = []
        # per-iteration scalar traces, one column per chain — the raw
        # material for split-R-hat / ESS in eval records
        self.trace: dict[str, list[np.ndarray]] = {"sigma_x": [], "K": []}
        self._chain_axis = cfg.driver == "multichain"
        self._build_backend()

    # ---- backend selection -------------------------------------------------
    def _build_backend(self) -> None:
        """Install the backend hooks:

        * ``_step(gs, st)`` / ``_stale(gs, st)`` — advance backend-NATIVE
          state ``st`` (HybridShard for vmap/multichain; mesh-layout
          buffers for shardmap, which stay device-resident across the
          whole hot loop — conversion happens only at eval/ckpt cadence,
          never per iteration).
        * ``_to_native(ss)`` / ``_to_shard(st)`` — convert between the
          canonical checkpoint layout and native state.
        """
        cfg = self.cfg
        if cfg.driver in ("vmap", "multichain"):
            it_fn = (hybrid_iteration_multichain if self._chain_axis
                     else hybrid_iteration_vmap)
            one = lambda fn, g, s: fn(self.Xs, g, s, self.hyp, L=cfg.L,
                                      N_global=self.N, backend=cfg.backend,
                                      collapsed_backend=cfg.collapsed_backend,
                                      chol_refresh=cfg.chol_refresh)
            self._step = lambda gs, ss: one(it_fn, gs, ss)
            if self._chain_axis:
                # built ONCE as jit(vmap(...)) — a bare vmap-of-jit would
                # re-trace the full iteration body on every stale pass
                self._stale = jax.jit(jax.vmap(
                    lambda g, s: one(hybrid_stale_pass, g, s)))
            else:
                self._stale = lambda gs, ss: one(hybrid_stale_pass, gs, ss)
            self._to_native = lambda ss: ss
            self._to_shard = lambda ss: ss
            return

        # shardmap: the production collective path, P devices on a data mesh
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as PS

        from repro.compat import make_mesh

        if cfg.P > jax.device_count():
            raise ValueError(
                f"driver='shardmap' needs P={cfg.P} devices, have "
                f"{jax.device_count()} (use --xla_force_host_platform_"
                f"device_count on CPU)"
            )
        mesh = make_mesh((cfg.P,), ("data",))
        raw = make_hybrid_iteration_shardmap(
            mesh, ("data",), self.hyp, L=cfg.L, N_global=self.N,
            backend=cfg.backend, sync=cfg.sync,
            collapsed_backend=cfg.collapsed_backend,
            chol_refresh=cfg.chol_refresh,
        )
        raw_stale = (
            make_hybrid_stale_pass_shardmap(
                mesh, ("data",), L=cfg.L, N_global=self.N,
                backend=cfg.backend,
                collapsed_backend=cfg.collapsed_backend,
                chol_refresh=cfg.chol_refresh,
            ) if cfg.stale_sync > 0 else None
        )
        sh = NamedSharding(mesh, PS("data"))
        Xf = jax.device_put(jnp.asarray(self.X_global), sh)

        def to_native(ss: HybridShard):
            P_, N_p, K = ss.Z.shape
            Kt = ss.Z_tail.shape[-1]
            return (
                jax.device_put(ss.Z.reshape(self.N, K), sh),
                jax.device_put(ss.Z_tail.reshape(self.N, Kt), sh),
                jax.device_put(ss.tail_active, sh),
            )

        def to_shard(st) -> HybridShard:
            Zf, Zt, ta = st
            P_, N_p = cfg.P, self.N // cfg.P
            return HybridShard(
                Z=Zf.reshape(P_, N_p, Zf.shape[-1]),
                Z_tail=Zt.reshape(P_, N_p, Zt.shape[-1]),
                tail_active=ta,
            )

        def step_with(fn, gs, st):
            gs2, Zf, Zt, ta = fn(Xf, gs, *st)
            return gs2, (Zf, Zt, ta)

        self._step = lambda gs, st: step_with(raw, gs, st)
        self._stale = lambda gs, st: step_with(raw_stale, gs, st)
        self._to_native = to_native
        self._to_shard = to_shard

    # ---- state <-> checkpoint layout (global Z for elastic resharding) ----
    def _init_state(self) -> tuple[HybridGlobal, HybridShard]:
        cfg = self.cfg
        kw = dict(
            K_tail=cfg.K_tail, alpha=cfg.alpha, sigma_x=cfg.sigma_x,
            sigma_a=cfg.sigma_a, K_init=cfg.K_init,
        )
        if self._chain_axis:
            return init_multichain(
                jax.random.key(cfg.seed), self.Xs, cfg.n_chains, cfg.K_max,
                **kw,
            )
        return init_hybrid(jax.random.key(cfg.seed), self.Xs, cfg.K_max, **kw)

    def _to_ckpt(self, gs: HybridGlobal, ss: HybridShard) -> dict:
        # tail buffers are NOT serialized: checkpoints are written post-sync,
        # where tails are always cleared — _from_ckpt rebuilds them empty at
        # the configured K_tail (which a restart may therefore resize)
        *lead, P, N_p, K = ss.Z.shape
        return {
            "gs": gs,
            "Z_global": ss.Z.reshape(*lead, P * N_p, K),
            "meta": {"it": gs.it},
        }

    def _from_ckpt(self, blob: dict) -> tuple[HybridGlobal, HybridShard]:
        cfg = self.cfg
        gs: HybridGlobal = blob["gs"]
        Zg = blob["Z_global"]
        K_ck = Zg.shape[-1]
        if K_ck > cfg.K_max:
            raise ValueError(
                f"checkpoint K_max={K_ck} exceeds configured {cfg.K_max}"
            )
        if K_ck < cfg.K_max:
            # capacity-growth restart: pad the feature axis with empty slots
            grow = cfg.K_max - K_ck
            Zg = _pad_trailing(Zg, -1, grow)
            gs = dataclasses.replace(
                gs,
                A=_pad_trailing(gs.A, -2, grow),
                pi=_pad_trailing(gs.pi, -1, grow),
                active=_pad_trailing(gs.active, -1, grow),
                overflow=jnp.zeros_like(gs.overflow),
            )
        *lead, N, K = Zg.shape
        # elastic P is a reshape of the observation axis — the checkpoint's
        # N must survive the new config's truncation and divide by P, else
        # fail with a message instead of a deep reshape/broadcast error
        if N != self.N:
            raise ValueError(
                f"checkpoint has N={N} observations but this driver "
                f"truncated the data to N={self.N} (P={cfg.P}); pick a P "
                f"that keeps N={N}"
            )
        # chain-axis compatibility is checked loudly: a single-chain
        # checkpoint must not silently restore under a chain-batched
        # template (or vice versa), and the chain count is part of the
        # state — n_chains cannot change across a restart
        if self._chain_axis:
            if not lead or lead[0] != cfg.n_chains:
                raise ValueError(
                    f"checkpoint chain axis {lead or 'absent'} does not "
                    f"match configured n_chains={cfg.n_chains}"
                )
        elif lead:
            raise ValueError(
                f"checkpoint carries a chain axis {lead}; restore it with "
                f"driver='multichain' and n_chains={lead[0]}"
            )
        P = cfg.P
        # tails are cleared at every master sync, and checkpoints are only
        # written post-sync — so tail buffers are rebuilt EMPTY at the
        # CONFIGURED K_tail (a restart may widen/narrow tail exploration;
        # the checkpoint's tail width does not pin it)
        ss = HybridShard(
            Z=Zg.reshape(*lead, P, N // P, K),
            Z_tail=jnp.zeros((*lead, P, N // P, cfg.K_tail), Zg.dtype),
            tail_active=jnp.zeros((*lead, P, cfg.K_tail), Zg.dtype),
        )
        return gs, ss

    def _template(self):
        gs, ss = self._init_state()
        return self._to_ckpt(gs, ss)

    # ---- main loop --------------------------------------------------------
    def run(self, n_iters: int | None = None,
            on_eval: Callable[[dict], None] | None = None,
            crash_at: int | None = None):
        """Main loop. ``crash_at`` raises mid-run (for restart tests)."""
        cfg = self.cfg
        n_iters = n_iters or cfg.n_iters
        restored = restore(cfg.ckpt_dir, self._template())
        if restored is not None:
            blob, start = restored[0], int(restored[1])
            gs, ss = self._from_ckpt(blob)
        else:
            start = 0
            gs, ss = self._init_state()

        t0 = time.time()
        st = self._to_native(ss)  # backend-native state, device-resident
        for it in range(start, n_iters):
            if crash_at is not None and it == crash_at:
                raise RuntimeError(f"injected crash at iteration {it}")
            for _ in range(cfg.stale_sync):
                gs, st = self._stale(gs, st)
            gs, st = self._step(gs, st)
            self._record_trace(gs)
            last = it == n_iters - 1
            need_eval = (it + 1) % cfg.eval_every == 0 or last
            need_ckpt = (it + 1) % cfg.ckpt_every == 0 or last
            # pulling gs.overflow blocks the host on the iteration's whole
            # computation, so check at a bounded cadence, not every step —
            # detection delay is <= overflow_every iterations (DESIGN.md §10)
            overflowed = (
                need_eval or need_ckpt
                or (it + 1) % cfg.overflow_every == 0
            ) and int(jnp.max(gs.overflow)) > 0
            if need_eval or need_ckpt or overflowed:
                # canonical layout is materialized at cadence only — the
                # hot loop never leaves the backend's native layout
                ss = self._to_shard(st)
            if need_eval:
                rec = self.evaluate(gs, ss, it + 1, time.time() - t0)
                self.history.append(rec)
                if on_eval:
                    on_eval(rec)
            if need_ckpt:
                save_pytree(cfg.ckpt_dir, self._to_ckpt(gs, ss), it + 1)
            if overflowed:
                # capacity growth: checkpoint + restart with larger K_max
                if not need_ckpt:
                    save_pytree(cfg.ckpt_dir, self._to_ckpt(gs, ss), it + 1)
                raise RuntimeError(
                    f"K_max={cfg.K_max} overflow at it={it}; restart with 2x K_max"
                )
        return gs, self._to_shard(st)

    # ---- diagnostics ------------------------------------------------------
    def _record_trace(self, gs: HybridGlobal) -> None:
        # keep DEVICE arrays: np.asarray here would block on every
        # iteration's whole computation and kill async dispatch — the
        # host sync is deferred to diagnostics() (eval cadence)
        self.trace["sigma_x"].append(jnp.atleast_1d(gs.sigma_x))
        self.trace["K"].append(jnp.atleast_1d(jnp.sum(gs.active, axis=-1)))

    def diagnostics(self, burn_frac: float = 0.5) -> dict[str, float]:
        """split-R-hat / ESS / MCSE of the monitored scalars over the
        post-burn tail of the per-iteration trace (DESIGN.md §11).
        R-hat is NaN until the trace has enough post-burn draws."""
        out: dict[str, float] = {}
        for name, rows in self.trace.items():
            # convert each device row to host numpy ONCE, in place —
            # releases the device buffer and keeps repeat evals linear
            for i, r in enumerate(rows):
                if not isinstance(r, np.ndarray):
                    rows[i] = np.asarray(r, np.float64)
            if len(rows) < 8:
                continue
            arr = np.stack(rows, axis=1)               # (C, T)
            tail = arr[:, int(burn_frac * arr.shape[1]):]
            s = convergence.summarize(tail, name)
            for k in ("rhat", "ess", "mcse"):
                out[f"{name}_{k}"] = s[f"{name}_{k}"]
        return out

    def evaluate(self, gs: HybridGlobal, ss: HybridShard, it: int,
                 elapsed: float) -> dict[str, Any]:
        X = jnp.asarray(self.X_global)
        if self._chain_axis:
            C = ss.Z.shape[0]
            Z = ss.Z.reshape(C, self.N, -1)
            lls = jax.vmap(
                train_joint_loglik, in_axes=(None, 0, 0, 0, 0, 0)
            )(X, Z, gs.A, gs.pi, gs.active, gs.sigma_x)
            Ks = np.asarray(jnp.sum(gs.active, axis=-1))
            rec: dict[str, Any] = {
                "it": it,
                "t": elapsed,
                "K": float(Ks.mean()),
                "K_chains": [int(k) for k in Ks],
                "alpha": float(jnp.mean(gs.alpha)),
                "sigma_x": float(jnp.mean(gs.sigma_x)),
                "sigma_x_chains": [float(s) for s in np.asarray(gs.sigma_x)],
                "joint_ll_train": float(jnp.mean(lls)),
                "joint_ll_train_chains": [float(l) for l in np.asarray(lls)],
            }
            if self.X_eval is not None:
                ev = jax.vmap(
                    lambda A, pi, act, sx, k: heldout_joint_loglik(
                        self.X_eval, A, pi, act, sx,
                        jax.random.fold_in(k, 999),
                    )
                )(gs.A, gs.pi, gs.active, gs.sigma_x, gs.key)
                rec["joint_ll_eval"] = float(jnp.mean(ev))
        else:
            Z = ss.Z.reshape(self.N, -1)
            rec = {
                "it": it,
                "t": elapsed,
                "K": int(jnp.sum(gs.active)),
                "alpha": float(gs.alpha),
                "sigma_x": float(gs.sigma_x),
                "joint_ll_train": float(train_joint_loglik(
                    X, Z, gs.A, gs.pi, gs.active, gs.sigma_x
                )),
            }
            if self.X_eval is not None:
                rec["joint_ll_eval"] = float(heldout_joint_loglik(
                    self.X_eval, gs.A, gs.pi, gs.active, gs.sigma_x,
                    jax.random.fold_in(gs.key, 999),
                ))
        rec.update(self.diagnostics())
        return rec
