from .driver import MCMCDriver, DriverConfig

__all__ = ["MCMCDriver", "DriverConfig"]
