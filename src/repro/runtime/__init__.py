from repro.core.ibp.api import Sampler, SamplerSpec, build_sampler

from .driver import DriverConfig, MCMCDriver, as_spec

__all__ = [
    "MCMCDriver",
    "DriverConfig",
    "SamplerSpec",
    "Sampler",
    "build_sampler",
    "as_spec",
]
