"""Composable sampler API (DESIGN.md §13): spec validation rejects every
invalid knob combination loudly; the historical drivers are degenerate
points of the chains x data grid; the composed mesh layout matches its
degenerate neighbours bitwise; and checkpoints interchange across all
four drivers (chain count preserved).

Multi-device cases run in subprocesses with forced host devices (same
pattern as tests/test_distributed.py — the main pytest process keeps a
single CPU device)."""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.ibp import IBPHypers, SamplerSpec, build_sampler
from repro.core.ibp.api import DRIVERS
from repro.data import cambridge_data
from repro.runtime import DriverConfig, MCMCDriver, as_spec

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_with_devices(code: str, n_devices: int = 4) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


# ---------------------------------------------------------------------------
# spec validation: every invalid combination fails loudly at construction
# ---------------------------------------------------------------------------

INVALID_SPECS = [
    # (kwargs, message fragment)
    (dict(chains="tree"), "chains"),
    (dict(data="pmap"), "data"),
    (dict(chains="vmap", data="shardmap"), "vmap"),
    (dict(n_chains=0, chains="vmap"), "n_chains"),
    (dict(n_chains=-1, chains="mesh"), "n_chains"),
    (dict(n_chains=2), "chain axis"),          # chains="none" default
    (dict(sync="lazy"), "sync"),
    (dict(sync="fused"), "fused"),             # fused needs data="shardmap"
    (dict(sync="fused", chains="mesh", data="vmap"), "fused"),
    (dict(backend="cuda"), "backend"),
    (dict(collapsed_backend="magic"), "collapsed_backend"),
    (dict(chol_refresh=0), "chol_refresh"),
    (dict(k_live_buckets="auto"), "k_live_buckets"),
    (dict(k_live_buckets=""), "k_live_buckets"),
    (dict(P=0), "P="),
    (dict(L=0), "L="),
    (dict(K_max=0), "K_max"),
    (dict(K_tail=0), "K_tail"),
    (dict(K_tail=64), "K_tail"),               # > K_max default 32: tail
    #                                            promotion needs free slots
    (dict(K_max=4, K_tail=8), "exceeds"),
    (dict(k_tail_grow=-1), "k_tail_grow"),
    (dict(K_init=33), "K_init"),               # > K_max default 32
    (dict(K_init=-1), "K_init"),
    (dict(stale_sync=-1), "stale_sync"),       # used to skip silently
    (dict(overflow_every=0), "overflow_every"),  # used to ZeroDivisionError
    (dict(n_iters=0), "n_iters"),
    (dict(eval_every=0), "eval_every"),
    (dict(ckpt_every=0), "ckpt_every"),
]


@pytest.mark.parametrize("kw,frag", INVALID_SPECS,
                         ids=[f"{list(kw)[0]}={list(kw.values())[0]}"
                              for kw, _ in INVALID_SPECS])
def test_spec_rejects_invalid_combinations(kw, frag):
    with pytest.raises(ValueError, match=frag):
        SamplerSpec(**kw)


def test_spec_valid_layout_grid():
    """Every supported chains x data combination constructs, and the
    historical driver names map onto the right grid points."""
    assert SamplerSpec().driver == "vmap"
    assert SamplerSpec(chains="vmap", n_chains=4).driver == "multichain"
    assert SamplerSpec(data="shardmap").driver == "shardmap"
    m = SamplerSpec(chains="mesh", data="shardmap", n_chains=2)
    assert m.driver == "mesh" and m.devices_needed == 2 * m.P
    # chains-mesh with simulated data shards is also a valid layout
    mv = SamplerSpec(chains="mesh", data="vmap", n_chains=2)
    assert mv.driver == "mesh" and mv.devices_needed == 2
    for name in DRIVERS:
        spec = SamplerSpec.for_driver(name, n_chains=2 if
                                      DRIVERS[name][0] != "none" else 1)
        assert spec.driver == name
    with pytest.raises(ValueError, match="driver"):
        SamplerSpec.for_driver("pmap")


def test_driverconfig_shim_maps_onto_spec():
    """The deprecated scattered-kwarg surface maps 1:1 onto the spec —
    and invalid old-style combinations still fail loudly (through spec
    validation now)."""
    cfg = DriverConfig(P=3, K_max=12, driver="multichain", n_chains=4,
                       stale_sync=2, collapsed_backend="ref",
                       ckpt_dir="/tmp/x")
    spec = as_spec(cfg)
    assert (spec.chains, spec.data) == ("vmap", "vmap")
    assert spec.n_chains == 4 and spec.P == 3 and spec.K_max == 12
    assert spec.stale_sync == 2 and spec.ckpt_dir == "/tmp/x"
    assert spec.collapsed_backend == "ref"
    # passing a spec through as_spec is the identity
    assert as_spec(spec) is spec
    with pytest.raises(ValueError):
        as_spec(DriverConfig(driver="nope"))
    with pytest.raises(ValueError):   # n_chains > 1 needs a chainful driver
        as_spec(DriverConfig(driver="vmap", n_chains=2))
    with pytest.raises(ValueError):   # fused sync needs a collective layout
        as_spec(DriverConfig(driver="vmap", sync="fused"))
    # the collapsed tail default is now the certified-equivalent fast path
    assert DriverConfig().collapsed_backend == "fast"
    assert SamplerSpec().collapsed_backend == "fast"
    # occupancy-adaptive packing defaults on and maps through the shim
    assert DriverConfig().k_live_buckets == "on"
    assert SamplerSpec().k_live_buckets == "on"
    assert as_spec(DriverConfig(k_live_buckets="off")).k_live_buckets == "off"


def test_k_live_buckets_off_selects_unpacked_carry():
    """k_live_buckets="off" keeps the pre-packing hybrid behavior: a
    sampler built either way runs, and (since the full-width packed and
    unpacked carries differ only in float path) both stay finite/sane."""
    X, _, _ = cambridge_data(N=24, seed=2)
    for mode in ("on", "off"):
        spec = SamplerSpec(P=2, K_max=8, K_tail=4, K_init=2, L=2,
                           k_live_buckets=mode)
        s = build_sampler(spec, IBPHypers(), X)
        gs, st = s.init(jax.random.key(0))
        gs, st = s.step(gs, st)
        assert np.isfinite(float(gs.sigma_x))
        assert 0 <= int(jnp.sum(gs.active)) <= spec.K_max


def test_build_sampler_rejects_insufficient_devices():
    """Mesh layouts check the device budget loudly at build time (the
    main pytest process has exactly one CPU device)."""
    X, _, _ = cambridge_data(N=24, seed=0)
    spec = SamplerSpec(P=4, chains="mesh", data="shardmap", n_chains=2)
    with pytest.raises(ValueError, match="devices"):
        build_sampler(spec, IBPHypers(), X)


def test_sampler_protocol_canonical_roundtrip():
    """init/step/stale/to_canonical/from_canonical work uniformly; the
    canonical layout round-trips bitwise."""
    X, _, _ = cambridge_data(N=24, seed=1)
    for spec in (SamplerSpec(P=2, K_max=8, K_tail=4, K_init=2, L=2),
                 SamplerSpec(P=2, K_max=8, K_tail=4, K_init=2, L=2,
                             chains="vmap", n_chains=2),
                 SamplerSpec(P=1, K_max=8, K_tail=4, K_init=2, L=2,
                             data="shardmap")):
        s = build_sampler(spec, IBPHypers(), X)
        gs, st = s.init(jax.random.key(0))
        gs, st = s.step(gs, st)
        gs, st = s.stale(gs, st)
        ss = s.to_canonical(st)
        assert ss.Z.shape[-3:] == (spec.P, 24 // spec.P, spec.K_max)
        st2 = s.from_canonical(ss)
        np.testing.assert_array_equal(np.asarray(s.to_canonical(st2).Z),
                                      np.asarray(ss.Z))


# ---------------------------------------------------------------------------
# composed mesh layout: bitwise-degenerate to its neighbours
# ---------------------------------------------------------------------------

def test_mesh_Cx1_matches_multichain_bitwise():
    """mesh with C chains x 1 data shard advances the SAME trajectories as
    the vmapped multichain layout: bitwise Z and PRNG keys, float scalars
    to reduction-order ULPs."""
    out = run_with_devices("""
        import jax, numpy as np
        from repro.core.ibp import IBPHypers, SamplerSpec, build_sampler
        from repro.data import cambridge_data
        X, _, _ = cambridge_data(N=48, sigma_n=0.4, seed=3)
        kw = dict(P=1, K_max=12, K_tail=6, K_init=3, L=2, n_chains=2)
        a = build_sampler(SamplerSpec(chains='mesh', data='shardmap', **kw),
                          IBPHypers(), X)
        b = build_sampler(SamplerSpec(chains='vmap', data='vmap', **kw),
                          IBPHypers(), X)
        ga, sa = a.init(jax.random.key(7))
        gb, sb = b.init(jax.random.key(7))
        for _ in range(5):
            ga, sa = a.step(ga, sa)
            gb, sb = b.step(gb, sb)
        np.testing.assert_array_equal(np.asarray(a.to_canonical(sa).Z),
                                      np.asarray(b.to_canonical(sb).Z))
        np.testing.assert_array_equal(
            np.asarray(jax.random.key_data(ga.key)),
            np.asarray(jax.random.key_data(gb.key)))
        np.testing.assert_allclose(np.asarray(ga.sigma_x),
                                   np.asarray(gb.sigma_x), rtol=1e-5)
        ga, sa = a.stale(ga, sa)
        gb, sb = b.stale(gb, sb)
        np.testing.assert_array_equal(np.asarray(a.to_canonical(sa).Z),
                                      np.asarray(b.to_canonical(sb).Z))
        print('OK mesh Cx1 == multichain')
    """, n_devices=2)
    assert "OK mesh Cx1 == multichain" in out


def test_mesh_1xP_matches_shardmap_bitwise():
    """mesh with 1 chain x P data shards computes the SAME step as the
    chainless shardmap layout from the same canonical state (init differs
    by design: chainful layouts split the key per chain)."""
    out = run_with_devices("""
        import jax, numpy as np
        from repro.core.ibp import (HybridShard, IBPHypers, SamplerSpec,
                                    build_sampler)
        from repro.data import cambridge_data
        X, _, _ = cambridge_data(N=48, sigma_n=0.4, seed=3)
        kw = dict(P=4, K_max=12, K_tail=6, K_init=3, L=2)
        c = build_sampler(SamplerSpec(chains='mesh', data='shardmap',
                                      n_chains=1, **kw), IBPHypers(), X)
        d = build_sampler(SamplerSpec(data='shardmap', **kw),
                          IBPHypers(), X)
        gd, sd = d.init(jax.random.key(9))
        ss_d = d.to_canonical(sd)
        gc = jax.tree.map(lambda x: x[None], gd)       # lift to C=1
        sc = c.from_canonical(HybridShard(
            Z=ss_d.Z[None], Z_tail=ss_d.Z_tail[None],
            tail_active=ss_d.tail_active[None]))
        for _ in range(5):
            gc, sc = c.step(gc, sc)
            gd, sd = d.step(gd, sd)
        np.testing.assert_array_equal(np.asarray(c.to_canonical(sc).Z)[0],
                                      np.asarray(d.to_canonical(sd).Z))
        np.testing.assert_array_equal(
            np.asarray(jax.random.key_data(gc.key))[0],
            np.asarray(jax.random.key_data(gd.key)))
        np.testing.assert_allclose(float(gc.sigma_x[0]), float(gd.sigma_x),
                                   rtol=1e-5)
        gc, sc = c.stale(gc, sc)
        gd, sd = d.stale(gd, sd)
        np.testing.assert_array_equal(np.asarray(c.to_canonical(sc).Z)[0],
                                      np.asarray(d.to_canonical(sd).Z))
        print('OK mesh 1xP == shardmap')
    """, n_devices=4)
    assert "OK mesh 1xP == shardmap" in out


# ---------------------------------------------------------------------------
# driver="mesh" end to end + checkpoint interchange across all four drivers
# ---------------------------------------------------------------------------

def test_mesh_driver_runs_and_interchanges_checkpoints():
    """driver='mesh' (2 chains x 2 data shards on 4 forced host devices)
    runs end to end through MCMCDriver, reports chain-axis diagnostics in
    eval records, and its checkpoints restore under driver='multichain'
    and back (chain count preserved)."""
    out = run_with_devices("""
        import dataclasses, math, tempfile
        from repro.core.ibp import IBPHypers
        from repro.data import cambridge_data
        from repro.runtime import DriverConfig, MCMCDriver
        X, _, _ = cambridge_data(N=48, sigma_n=0.4, seed=3)
        d = tempfile.mkdtemp()
        cfg = DriverConfig(P=2, K_max=12, K_tail=6, L=2, n_iters=16,
                           ckpt_every=8, eval_every=16, driver='mesh',
                           n_chains=2, stale_sync=1, ckpt_dir=d)
        drv = MCMCDriver(X, cfg, IBPHypers())
        gs, ss = drv.run()
        assert ss.Z.shape[0] == 2, ss.Z.shape      # chain axis preserved
        rec = drv.history[-1]
        assert 'sigma_x_rhat' in rec and len(rec['K_chains']) == 2
        assert math.isfinite(rec['sigma_x_rhat'])
        # mesh checkpoint -> multichain (elastic P too: 2 -> 4 data shards)
        cfg_mc = dataclasses.replace(cfg, driver='multichain', P=4,
                                     n_iters=20)
        gs2, ss2 = MCMCDriver(X, cfg_mc, IBPHypers()).run()
        assert int(gs2.it.max()) == 20 and ss2.Z.shape[0] == 2
        # multichain checkpoint -> mesh
        cfg_m2 = dataclasses.replace(cfg, n_iters=24)
        gs3, ss3 = MCMCDriver(X, cfg_m2, IBPHypers()).run()
        assert int(gs3.it.max()) == 24 and ss3.Z.shape[0] == 2
        # changing the chain count across a restart still fails loudly
        try:
            MCMCDriver(X, dataclasses.replace(cfg, n_chains=3, P=1,
                                              n_iters=30),
                       IBPHypers()).run()
            raise SystemExit('expected chain-count mismatch to raise')
        except ValueError as e:
            assert 'n_chains' in str(e)
        print('OK mesh driver + ckpt interchange')
    """, n_devices=4)
    assert "OK mesh driver + ckpt interchange" in out


def test_checkpoint_interchange_chainless_drivers(tmp_path):
    """vmap-written checkpoints restore under shardmap and back (the
    chainless half of the four-driver interchange; P=1 mesh runs
    in-process on the single CPU device)."""
    X, _, _ = cambridge_data(N=24, sigma_n=0.4, seed=5)
    mk = lambda driver, n: DriverConfig(
        P=1, K_max=12, K_tail=4, L=2, n_iters=n, ckpt_every=4,
        eval_every=100, driver=driver, ckpt_dir=str(tmp_path))
    MCMCDriver(X, mk("vmap", 4), IBPHypers()).run()
    gs, ss = MCMCDriver(X, mk("shardmap", 8), IBPHypers()).run()
    assert int(gs.it) == 8
    gs2, ss2 = MCMCDriver(X, mk("vmap", 12), IBPHypers()).run()
    assert int(gs2.it) == 12 and ss2.Z.shape == ss.Z.shape


def test_stale_sync_validation_rejects_negative():
    """The satellite fix: stale_sync=-1 used to silently skip the stale
    loop; overflow_every=0 used to crash with a bare ZeroDivisionError in
    run(). Both are rejected at config time now, through both surfaces."""
    with pytest.raises(ValueError, match="stale_sync"):
        SamplerSpec(stale_sync=-1)
    with pytest.raises(ValueError, match="stale_sync"):
        as_spec(DriverConfig(stale_sync=-1))
    with pytest.raises(ValueError, match="overflow_every"):
        SamplerSpec(overflow_every=0)
    with pytest.raises(ValueError, match="overflow_every"):
        as_spec(DriverConfig(overflow_every=0))
