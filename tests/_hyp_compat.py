"""Optional-``hypothesis`` shim for property tests.

When hypothesis is installed (CI, via requirements-dev.txt) the property
tests run under real ``@given`` search. When it is not, the same test
functions run under ``pytest.mark.parametrize`` over a fixed-seed sample
of the declared ranges — deterministic, collection never fails.
"""
from __future__ import annotations

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False


def given_or_params(max_examples: int = 20, **ranges):
    """Decorator: hypothesis ``@given`` over the ranges, or a fixed-seed
    parametrized fallback.

    Each range is an inclusive ``(lo, hi)`` pair; int pairs become
    integer draws, float pairs become uniform draws.
    """
    names = list(ranges)

    if HAVE_HYPOTHESIS:
        strats = {}
        for k, (lo, hi) in ranges.items():
            if isinstance(lo, int) and isinstance(hi, int):
                strats[k] = st.integers(lo, hi)
            else:
                strats[k] = st.floats(
                    lo, hi, allow_nan=False, allow_infinity=False
                )

        def deco(f):
            return settings(max_examples=max_examples, deadline=None)(
                given(**strats)(f)
            )

        return deco

    rng = np.random.default_rng(0)
    cases = []
    for _ in range(max_examples):
        vals = []
        for k in names:
            lo, hi = ranges[k]
            if isinstance(lo, int) and isinstance(hi, int):
                vals.append(int(rng.integers(lo, hi + 1)))
            else:
                vals.append(float(rng.uniform(lo, hi)))
        cases.append(tuple(vals))

    def deco(f):
        return pytest.mark.parametrize(",".join(names), cases)(f)

    return deco
