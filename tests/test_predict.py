"""Posterior-predictive serving subsystem (core/ibp/predict, DESIGN.md §15):

* encode's Rao-Blackwellized Gibbs marginals vs the exact 2^K
  enumeration oracle at small K;
* impute equals the exact conditional mean in the sigma -> 0 limit;
* bank save/restore roundtrip, including mixed live-K buckets across
  samples and bucket-ladder packing;
* the batched per-row joint log-likelihood (and the logsumexp mixture)
  vs the naive float64 numpy oracle to 1e-6;
* driver harvest integration (chain-aware, restorable with no sampler
  state) and the harvest spec knobs' validation;
* the mesh-sharded scorer vs the unsharded op;
* serve_ibp's pad-to-bucket microbatching helpers.
"""
from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.ibp import IBPHypers, SamplerSpec
from repro.core.ibp import predict
from repro.core.ibp.predict import BankBuilder, SampleBank


def make_bank(S=3, K_max=16, K_live=5, D=8, sigma_x=0.6, seed=0,
              k_lives=None):
    rng = np.random.default_rng(seed)
    bb = BankBuilder(K_max)
    lives = k_lives if k_lives is not None else [K_live] * S
    for s, kl in enumerate(lives):
        act = np.zeros(K_max, np.float32)
        act[:kl] = 1.0
        bb.add(rng.normal(size=(K_max, D)).astype(np.float32) * act[:, None],
               rng.uniform(0.2, 0.8, K_max).astype(np.float32) * act,
               act, sigma_x, 1.0, 2.0, chain=s % 2, it=10 + s)
    return bb.build()


# --------------------------------------------------------------------------
# encode vs exact enumeration
# --------------------------------------------------------------------------


def test_encode_matches_enumeration_small_k():
    """RB'd Gibbs marginals converge to the exact 2^K posterior."""
    bank = make_bank(S=2, K_max=8, K_live=4, D=6, sigma_x=0.8, seed=1)
    rng = np.random.default_rng(2)
    X = rng.normal(size=(5, 6)).astype(np.float32)
    probs = predict.encode(bank, X, jax.random.key(0), n_sweeps=192)
    for s in range(bank.S):
        marg, _, _ = predict.exact_posterior(
            bank.A[s], bank.pi[s], bank.active[s], bank.sigma_x[s], X)
        err = np.max(np.abs(np.asarray(probs[s]) - np.asarray(marg)))
        assert err < 0.12, f"sample {s}: RB marginals off by {err}"


def test_encode_masked_matches_masked_enumeration():
    """Masked-Gaussian conditioning: only observed dims enter."""
    bank = make_bank(S=1, K_max=8, K_live=3, D=6, sigma_x=0.8, seed=3)
    rng = np.random.default_rng(4)
    X = rng.normal(size=(4, 6)).astype(np.float32)
    mask = (rng.random((4, 6)) > 0.4).astype(np.float32)
    mask[:, 0] = 1.0
    probs = predict.encode(bank, X, jax.random.key(1), mask=mask,
                           n_sweeps=192)
    marg, _, _ = predict.exact_posterior(
        bank.A[0], bank.pi[0], bank.active[0], bank.sigma_x[0], X,
        mask=mask)
    err = np.max(np.abs(np.asarray(probs[0]) - np.asarray(marg)))
    assert err < 0.12, f"masked RB marginals off by {err}"


def test_exact_posterior_rejects_large_k():
    A = np.zeros((predict.ENUM_MAX_K + 1, 4), np.float32)
    with pytest.raises(ValueError, match="enumeration"):
        predict.exact_posterior(A, np.zeros(A.shape[0]),
                                np.zeros(A.shape[0]), 1.0,
                                np.zeros((2, 4), np.float32))


# --------------------------------------------------------------------------
# impute: sigma -> 0 limit
# --------------------------------------------------------------------------


def test_impute_sigma_zero_limit_equals_exact_conditional_mean():
    """As sigma_x -> 0 the posterior concentrates and E[x_miss | x_obs]
    is the exact conditional mean — which the enumeration oracle
    computes and the Gibbs imputation must match."""
    rng = np.random.default_rng(5)
    K_max, D = 8, 10
    A = np.zeros((K_max, D), np.float32)
    A[:3] = rng.normal(size=(3, D)).astype(np.float32)
    act = np.zeros(K_max, np.float32)
    act[:3] = 1.0
    bb = BankBuilder(K_max)
    sigma = 0.02
    bb.add(A, 0.5 * act, act, sigma, 1.0, 2.0)
    bank = bb.build()
    z_true = np.array([1.0, 0.0, 1.0])
    x_full = z_true @ A[:3]
    mask = np.ones((1, D), np.float32)
    mask[0, 6:] = 0.0  # last 4 dims missing
    X = (x_full * mask[0]).reshape(1, D).astype(np.float32)
    out = predict.impute(bank, X, mask, jax.random.key(2), n_sweeps=24)
    _, _, cond_mean = predict.exact_posterior(
        bank.A[0], bank.pi[0], bank.active[0], bank.sigma_x[0], X,
        mask=mask)
    miss = mask[0] < 0.5
    np.testing.assert_allclose(np.asarray(out)[0, miss],
                               np.asarray(cond_mean)[0, miss], atol=1e-2)
    np.testing.assert_allclose(np.asarray(out)[0, miss], x_full[miss],
                               atol=1e-2)
    # observed entries pass through untouched
    np.testing.assert_array_equal(np.asarray(out)[0, ~miss],
                                  X[0, ~miss])


# --------------------------------------------------------------------------
# bank packing + persistence
# --------------------------------------------------------------------------


def test_bank_packs_to_bucket_ladder():
    bank = make_bank(S=3, K_max=64, K_live=5, D=4)
    assert bank.K == 8  # smallest ladder bucket holding 5 live features


def test_bank_roundtrip_mixed_live_buckets(tmp_path):
    """Samples from different occupancy regimes pack to ONE bank bucket
    and survive save/load bitwise."""
    bank = make_bank(S=4, K_max=32, D=6, k_lives=[2, 9, 4, 7], seed=7)
    assert bank.K == 16  # bucket for the widest live set (9)
    path = str(tmp_path / "bank.npz")
    bank.save(path)
    back = SampleBank.load(path)
    import dataclasses
    for f in dataclasses.fields(SampleBank):
        np.testing.assert_array_equal(
            np.asarray(getattr(bank, f.name)),
            np.asarray(getattr(back, f.name)), err_msg=f.name)
    # and the restored bank scores identically
    X = np.random.default_rng(8).normal(size=(3, 6)).astype(np.float32)
    key = jax.random.key(3)
    np.testing.assert_array_equal(
        np.asarray(predict.predictive_loglik(bank, X, key)),
        np.asarray(predict.predictive_loglik(back, X, key)))


def test_bank_load_rejects_wrong_format(tmp_path):
    from repro.checkpoint import save_arrays
    path = str(tmp_path / "bad.npz")
    save_arrays(path, {"_format": np.asarray(99), "A": np.zeros((1, 2, 2))})
    with pytest.raises(ValueError, match="format"):
        SampleBank.load(path)


def test_empty_builder_build_raises():
    with pytest.raises(ValueError, match="empty bank"):
        BankBuilder(8).build()


# --------------------------------------------------------------------------
# predictive_loglik vs the numpy oracle (1e-6)
# --------------------------------------------------------------------------


def test_rows_joint_loglik_matches_numpy_oracle_1e6():
    """The jitted batched scorer's per-row joint ll (and its logsumexp
    mixture) match the explicit float64 numpy loop to 1e-6."""
    from jax.experimental import enable_x64

    with enable_x64():
        rng = np.random.default_rng(9)
        S, K, D, B = 3, 6, 7, 4
        bank = make_bank(S=S, K_max=8, K_live=5, D=D, seed=9)
        bank = jax.tree.map(
            lambda x: jnp.asarray(np.asarray(x), jnp.float64)
            if np.asarray(x).dtype.kind == "f" else jnp.asarray(x), bank)
        X = jnp.asarray(rng.normal(size=(B, D)))
        mask = jnp.asarray((rng.random((B, D)) > 0.3).astype(np.float64))
        _, Z, lls = predict._score_bank(
            bank, X, mask, jax.random.key(4), 3, 1, masked=True)
        oracle = np.stack([
            predict.joint_loglik_np(X, Z[s], bank.A[s], bank.pi[s],
                                    bank.active[s], bank.sigma_x[s],
                                    mask=mask)
            for s in range(S)
        ])
        np.testing.assert_allclose(np.asarray(lls), oracle,
                                   rtol=1e-6, atol=1e-6)
        mix = jax.scipy.special.logsumexp(jnp.asarray(oracle), axis=0) \
            - np.log(S)
        got, per = predict.predictive_loglik(
            bank, X, jax.random.key(4), mask=mask, per_sample=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(mix),
                                   rtol=1e-6, atol=1e-6)


def test_heldout_joint_loglik_is_canonical_reexport():
    """diagnostics re-exports predict's implementation (dedup)."""
    from repro.core.ibp import diagnostics
    assert diagnostics.heldout_joint_loglik is predict.heldout_joint_loglik
    assert diagnostics.train_joint_loglik is predict.train_joint_loglik


def test_anomaly_is_negative_mixture():
    bank = make_bank()
    X = np.random.default_rng(11).normal(size=(3, 8)).astype(np.float32)
    key = jax.random.key(5)
    np.testing.assert_array_equal(
        np.asarray(predict.anomaly_score(bank, X, key)),
        -np.asarray(predict.predictive_loglik(bank, X, key)))


def test_naive_loop_finite_and_shaped():
    bank = make_bank()
    X = np.random.default_rng(12).normal(size=(5, 8)).astype(np.float32)
    out = predict.predictive_loglik_naive(bank, X, jax.random.key(6))
    assert out.shape == (5,)
    assert np.all(np.isfinite(np.asarray(out)))


# --------------------------------------------------------------------------
# harvest wiring: spec validation + driver integration
# --------------------------------------------------------------------------


def test_spec_validates_harvest_knobs():
    with pytest.raises(ValueError, match="harvest_every"):
        SamplerSpec(harvest_every=-1)
    with pytest.raises(ValueError, match="harvest_burn"):
        SamplerSpec(harvest_burn=1.0)
    with pytest.raises(ValueError, match="harvest_burn"):
        SamplerSpec(harvest_burn=-0.1)
    SamplerSpec(harvest_every=5, harvest_burn=0.0)  # valid


def test_driver_harvests_chain_aware_bank(tmp_path):
    """A multichain run harvests one sample per chain past burn-in, the
    bank rides the checkpoint cadence, and the persisted npz restores
    with NO sampler state."""
    from repro.runtime import MCMCDriver

    rng = np.random.default_rng(13)
    X = rng.normal(size=(24, 5)).astype(np.float32)
    spec = SamplerSpec(
        P=2, K_max=8, K_tail=4, K_init=2, L=2, n_iters=8, eval_every=4,
        ckpt_every=4, ckpt_dir=str(tmp_path / "ck"),
        chains="vmap", data="vmap", n_chains=2,
        harvest_every=2, harvest_burn=0.25,
        bank_path=str(tmp_path / "bank.npz"),
    )
    drv = MCMCDriver(X, spec, IBPHypers())
    drv.run()
    # burn = int(0.25 * 8) = 2 -> harvests at iterations 4, 6, 8 x 2 chains
    assert len(drv.bank_builder) == 6
    bank = SampleBank.load(str(tmp_path / "bank.npz"))
    assert bank.S == 6
    assert sorted(set(np.asarray(bank.chain).tolist())) == [0, 1]
    assert sorted(set(np.asarray(bank.it).tolist())) == [4, 6, 8]
    # the bank is a bucket of K_max=8 at most
    assert bank.K <= 8
    # and it scores data without any sampler machinery
    ll = predict.predictive_loglik(bank, X[:4], jax.random.key(0))
    assert np.all(np.isfinite(np.asarray(ll)))


def test_driver_restart_extends_bank(tmp_path):
    """A restart re-seeds the builder from the persisted bank instead of
    overwriting it with a shorter ensemble."""
    from repro.runtime import MCMCDriver

    rng = np.random.default_rng(14)
    X = rng.normal(size=(16, 4)).astype(np.float32)
    kw = dict(P=2, K_max=8, K_tail=4, K_init=2, L=2, eval_every=4,
              ckpt_every=2, ckpt_dir=str(tmp_path / "ck"),
              harvest_every=1, harvest_burn=0.0,
              bank_path=str(tmp_path / "bank.npz"))
    drv = MCMCDriver(X, SamplerSpec(n_iters=4, **kw), IBPHypers())
    with pytest.raises(RuntimeError, match="injected crash"):
        drv.run(crash_at=3)  # harvested its 1, 2; ckpt at 2
    drv2 = MCMCDriver(X, SamplerSpec(n_iters=4, **kw), IBPHypers())
    drv2.run()
    bank = SampleBank.load(str(tmp_path / "bank.npz"))
    # resumed from the step-2 checkpoint with its 2 persisted samples,
    # then harvested 3 and 4
    assert bank.S == 4
    assert sorted(np.asarray(bank.it).tolist()) == [1, 2, 3, 4]


def test_same_driver_rerun_does_not_duplicate_harvests(tmp_path):
    """Retrying run() on the SAME driver object after a crash rewinds to
    the checkpoint and re-harvests the rewound iterations — the builder
    must reconcile (prune past the restored step) so every draw appears
    exactly once."""
    from repro.runtime import MCMCDriver

    rng = np.random.default_rng(21)
    X = rng.normal(size=(16, 4)).astype(np.float32)
    spec = SamplerSpec(
        P=2, K_max=8, K_tail=4, K_init=2, L=2, n_iters=4, eval_every=4,
        ckpt_every=2, ckpt_dir=str(tmp_path / "ck"),
        harvest_every=1, harvest_burn=0.0,
        bank_path=str(tmp_path / "bank.npz"))
    drv = MCMCDriver(X, spec, IBPHypers())
    with pytest.raises(RuntimeError, match="injected crash"):
        drv.run(crash_at=3)  # harvested 1..3 in memory; ckpt at 2
    drv.run()  # same object: rewinds to 2, re-runs 3 and 4
    its = sorted(np.asarray(SampleBank.load(spec.bank_path).it).tolist())
    assert its == [1, 2, 3, 4], its


# --------------------------------------------------------------------------
# mesh-sharded scoring
# --------------------------------------------------------------------------


def test_sharded_scorer_matches_unsharded():
    from repro.compat import make_mesh

    bank = make_bank(S=2, K_max=8, K_live=3, D=6, seed=15)
    X = np.random.default_rng(16).normal(size=(6, 6)).astype(np.float32)
    mesh = make_mesh((1,), ("data",))
    score = predict.make_sharded_scorer(bank, mesh, n_sweeps=3)
    key = jax.random.key(7)
    got = np.asarray(score(jnp.asarray(X), key))
    # one shard folds in axis index 0
    want = np.asarray(predict.predictive_loglik(
        bank, X, jax.random.fold_in(key, 0), n_sweeps=3))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


# --------------------------------------------------------------------------
# serve_ibp microbatching helpers
# --------------------------------------------------------------------------


def test_serve_row_buckets_and_padding():
    from repro.launch.serve_ibp import pad_to_bucket, row_buckets

    assert row_buckets(256) == (8, 16, 32, 64, 128, 256)
    assert row_buckets(8) == (8,)
    assert row_buckets(48) == (8, 16, 32, 48)
    bs = row_buckets(64)
    X = np.ones((5, 3), np.float32)
    P = pad_to_bucket(X, bs)
    assert P.shape == (8, 3)
    np.testing.assert_array_equal(P[:5], X)
    assert not P[5:].any()
    assert pad_to_bucket(np.ones((16, 3), np.float32), bs).shape == (16, 3)
