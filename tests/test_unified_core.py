"""Bitwise certification of the core unification (DESIGN.md §12).

The legacy unpacked fast row step (``_row_step_fast`` + ``_FastCarry``)
was deleted when the packed scan became THE single implementation of
the carried collapsed row step: ``k_live_buckets="off"`` (and the
in-jit ``collapsed_row_scan(pack=False)`` route) now run ``_packed_scan``
at the TOP bucket — B = K_max, identity column permutation, G carry
disabled — which is claimed to be BITWISE-identical to the deleted
code, not merely decision-equivalent within a mismatch budget.

This test pins that claim: the deleted row step is embedded below
VERBATIM (from the pre-unification revision; the only adaptation is
the extra ``sat`` output of ``_sample_dishes``, which consumes no
randomness) and scanned against ``collapsed_row_scan(backend="fast",
pack=False)`` on the seed grid. Every array in the carry — Z, active,
the integer sufficient statistics, AND the float m — must agree
exactly, across multiple chained scans (so refresh, drop and birth
paths are all exercised), for both birth flavors.
"""
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.ibp import init_state
from repro.core.ibp import math as ibm
from repro.core.ibp.collapsed import (
    PROBE_EVERY,
    _exact_factor,
    _sample_dishes,
    collapsed_row_scan,
)
from repro.data import cambridge_data
from repro.kernels.collapsed_row import collapsed_row_flip

Array = jax.Array


# --------------------------------------------------------------------------
# The DELETED legacy unpacked fast row step, embedded verbatim as the
# reference this test certifies against. Do not "improve" this code: its
# value is that it is the exact pre-unification float path.
# --------------------------------------------------------------------------
class _FastCarry(NamedTuple):
    Z: Array
    active: Array
    ZtZ: Array
    ZtX: Array
    m: Array
    Lt: Array
    M: Array
    H: Array
    since: Array
    n_refresh: Array
    key: Array


def _row_step_fast(carry: _FastCarry, n, *, X, N, D, birth, alpha, sx, sa,
                   refresh_every, drift_tol, flip_flavor):
    Z, active, ZtZ, ZtX, m, Lt, M, H, since, n_refresh, key = carry
    x_n = X[n]
    z_old = Z[n]
    ratio = (sx / sa) ** 2
    m_minus = m - z_old
    zu = z_old * active
    w = M @ zu
    p_down = Lt @ w
    down_ok = jnp.all(1.0 - jnp.cumsum(p_down * p_down) > 1e-12)
    gamma = jnp.dot(zu, w)
    delta_s = jnp.maximum(1.0 - gamma, 1e-6)
    zH = zu @ H
    wr = w / jnp.sqrt(delta_s)
    wd = w / delta_s
    M1 = M + jnp.outer(wr, wr)
    H1 = H + jnp.outer(wd, zH - x_n)
    drop = active * (m_minus <= 0.5)
    z = z_old * (1.0 - drop)
    active_m = active * (1.0 - drop)
    has_drop = jnp.any(drop > 0.5)

    def do_drop(ops):
        M1, H1 = ops
        keep2 = ibm.mask_outer(active_m)
        return M1 * keep2, H1 * active_m[:, None]

    M1, H1 = jax.lax.cond(has_drop, do_drop, lambda ops: ops, (M1, H1))

    def do_probe(_):
        tm = ZtZ @ active_m - z_old * jnp.dot(z_old, active_m)
        probe_t = active_m * tm + ratio * active_m
        return jnp.max(jnp.abs(M1 @ probe_t - active_m))

    drift = jax.lax.cond(
        since % PROBE_EVERY == 0, do_probe, lambda _: jnp.zeros((), X.dtype),
        None,
    )
    need = (since >= refresh_every - 1) | (~down_ok) | (~(drift <= drift_tol))

    def do_refresh(_):
        ZtZ_m = ZtZ - jnp.outer(z_old, z_old)
        ZtX_m = ZtX - jnp.outer(z_old, x_n)
        L2, M2 = ibm.chol_inv(ibm.padded_W(ZtZ_m, active_m, ratio))
        M2 = M2 * ibm.mask_outer(active_m)
        return L2.T, M2, M2 @ (ZtX_m * active_m[:, None])

    Lt_rm, M1, H1 = jax.lax.cond(
        need, do_refresh, lambda _: (Lt, M1, H1), None
    )
    since = jnp.where(need, 0, since + 1)
    n_refresh = n_refresh + need.astype(n_refresh.dtype)

    inv2s2 = 0.5 / (sx**2)
    K = Z.shape[1]
    key, kbits, kdish, kslot = jax.random.split(key, 4)
    uu = jnp.clip(jax.random.uniform(kbits, (K,), dtype=X.dtype), 1e-7,
                  1.0 - 1e-7)
    u = jnp.log(uu) - jnp.log1p(-uu)

    def vqm_closed(_):
        gd = gamma / delta_s
        return wd, gd, zH + gd * (zH - x_n)

    def vqm_matvec(_):
        v = M1 @ z
        return v, jnp.dot(z, v), z @ H1

    v, q, mean = jax.lax.cond(
        has_drop | need, vqm_matvec, vqm_closed, None
    )
    z, v, q, mean = collapsed_row_flip(
        M1, H1, x_n, z, v, q, mean, u, m_minus, active_m, N, inv2s2,
        flavor=flip_flavor,
    )

    # (adaptation: _sample_dishes now also returns the saturation flag —
    # it consumes no randomness and does not perturb the legacy stream)
    z, active_new, newbits, _, _ = _sample_dishes(
        kdish, q, mean, x_n, active_m, z, alpha, sx, sa, N, D, birth
    )

    m_new = m_minus * active_m + z
    changed = (
        need | jnp.any(z != z_old) | jnp.any(active_new != active)
    )

    def stats_moved(_):
        def masked(_):
            return ((ZtZ - jnp.outer(z_old, z_old))
                    * ibm.mask_outer(active_m) + jnp.outer(z, z),
                    (ZtX - jnp.outer(z_old, x_n)) * active_m[:, None]
                    + jnp.outer(z, x_n))

        def fused(_):
            return (ZtZ + jnp.outer(z, z) - jnp.outer(z_old, z_old),
                    ZtX + jnp.outer(z - z_old, x_n))

        return jax.lax.cond(has_drop, masked, fused, None)

    ZtZ_n, ZtX_n = jax.lax.cond(
        changed | has_drop, stats_moved, lambda _: (ZtZ, ZtX), None
    )

    def apply_moves(_):
        Lt1 = jax.lax.cond(
            need,
            lambda __: Lt_rm,
            lambda __: ibm.chol_rank1_downdate_t(Lt, p_down)[0],
            None,
        )

        def diag_swaps(ops):
            Lt1, M1, H1 = ops
            keep2 = ibm.mask_outer(active_m)
            Lt1 = Lt1 * keep2 + jnp.diag(1.0 - active_m)
            Lt1 = Lt1 + jnp.diag(newbits * (jnp.sqrt(ratio) - 1.0))
            M1b = M1 + jnp.diag(newbits / ratio)
            H1b = H1 * (1.0 - newbits)[:, None]
            return Lt1, M1b, H1b

        Lt1, M1b, H1b = jax.lax.cond(
            has_drop | jnp.any(newbits > 0.5), diag_swaps, lambda ops: ops,
            (Lt1, M1, H1),
        )
        w2 = M1b @ z
        Lt2 = ibm.chol_rank1_update_t(Lt1, Lt1 @ w2)
        d2 = 1.0 + jnp.dot(z, w2)
        w2r = w2 / jnp.sqrt(d2)
        M2 = M1b - jnp.outer(w2r, w2r)
        H2 = H1b + jnp.outer(w2 / d2, x_n - z @ H1b)
        return Lt2, M2, H2

    Lt_n, M_n, H_n = jax.lax.cond(
        changed, apply_moves, lambda _: (Lt, M, H), None
    )
    Z = Z.at[n].set(z)
    return _FastCarry(
        Z=Z, active=active_new, ZtZ=ZtZ_n, ZtX=ZtX_n, m=m_new,
        Lt=Lt_n, M=M_n, H=H_n, since=since, n_refresh=n_refresh, key=key,
    ), None


def _legacy_row_scan(Z, active, ZtZ, ZtX, m, X, key, alpha, sx, sa, *,
                     N, birth, refresh_every, drift_tol):
    """The deleted unpacked fast branch of ``collapsed_row_scan``,
    verbatim (flip_flavor="packed" was the non-pallas fast path)."""
    n_rows, D = X.shape
    rows = jnp.arange(n_rows)
    ratio = (sx / sa) ** 2
    Lt, M, H = _exact_factor(ZtZ, ZtX, active, ratio)
    body = partial(
        _row_step_fast, X=X, N=N, D=D, birth=birth,
        alpha=alpha, sx=sx, sa=sa,
        refresh_every=refresh_every, drift_tol=drift_tol,
        flip_flavor="packed",
    )
    carry = _FastCarry(
        Z=Z, active=active, ZtZ=ZtZ, ZtX=ZtX, m=m, Lt=Lt, M=M, H=H,
        since=jnp.zeros((), jnp.int32), n_refresh=jnp.zeros((), jnp.int32),
        key=key,
    )
    carry, _ = jax.lax.scan(body, carry, rows)
    return (carry.Z, carry.active, carry.ZtZ, carry.ZtX, carry.m,
            carry.n_refresh)


# --------------------------------------------------------------------------
# the certification
# --------------------------------------------------------------------------
@pytest.fixture(scope="module")
def data():
    X, _, _ = cambridge_data(N=100, sigma_n=0.4, seed=3)
    return jnp.asarray(X)


def _init_stats(X, seed, K_max=16):
    N, D = X.shape
    st = init_state(jax.random.key(seed), N, D, K_max=K_max, K_init=3)
    Z, active = st.Z, st.active
    m = jnp.sum(Z * active[None, :], axis=0)
    ZtZ = (Z.T @ Z) * ibm.mask_outer(active)
    ZtX = (Z.T @ X) * active[:, None]
    return (Z, active, ZtZ, ZtX, m), (st.alpha, st.sigma_x, st.sigma_a)


def _chain(X, seed, birth, refresh_every, n_scans, runner):
    """Thread ``n_scans`` row scans through ``runner``; per-scan keys are
    folded from a shared base so both implementations see identical
    randomness without needing the carry's key output."""
    (Z, active, ZtZ, ZtX, m), (alpha, sx, sa) = _init_stats(X, seed)
    base = jax.random.key(1000 + seed)
    out = None
    for i in range(n_scans):
        key = jax.random.fold_in(base, i)
        Z, active, ZtZ, ZtX, m, n_refresh = runner(
            Z, active, ZtZ, ZtX, m, X, key, alpha, sx, sa,
            birth=birth, refresh_every=refresh_every)
        out = (Z, active, ZtZ, ZtX, m, n_refresh)
    return out


def _run_unified(Z, active, ZtZ, ZtX, m, X, key, alpha, sx, sa, *,
                 birth, refresh_every):
    Z, active, ZtZ, ZtX, m, n_refresh, _ = collapsed_row_scan(
        Z, active, ZtZ, ZtX, m, X, key, alpha, sx, sa,
        N=float(X.shape[0]), birth=birth, backend="fast",
        refresh_every=refresh_every, pack=False)
    return Z, active, ZtZ, ZtX, m, n_refresh


def _run_legacy(Z, active, ZtZ, ZtX, m, X, key, alpha, sx, sa, *,
                birth, refresh_every):
    return _legacy_row_scan(
        Z, active, ZtZ, ZtX, m, X, key, alpha, sx, sa,
        N=float(X.shape[0]), birth=birth, refresh_every=refresh_every,
        drift_tol=1e-2)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
@pytest.mark.parametrize("refresh", [8, 32])
def test_top_bucket_bitwise_matches_deleted_unpacked_path(
        data, seed, refresh):
    """The unified packed core at B = K_max (G carry off) IS the deleted
    unpacked carry, bit for bit — every carry array, chained scans."""
    a = _chain(data, seed, "gibbs", refresh, n_scans=3, runner=_run_legacy)
    b = _chain(data, seed, "gibbs", refresh, n_scans=3, runner=_run_unified)
    for name, x, y in zip(("Z", "active", "ZtZ", "ZtX", "m", "n_refresh"),
                          a, b):
        np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y),
            err_msg=f"{name} diverged (seed={seed}, refresh={refresh})")


@pytest.mark.parametrize("seed", [0, 1])
def test_top_bucket_bitwise_matches_legacy_mh_births(data, seed):
    """Same certification under the MH birth flavor (the saturation
    counter's branch) — the sat extraction must not perturb the stream."""
    a = _chain(data, seed, "mh", 16, n_scans=3, runner=_run_legacy)
    b = _chain(data, seed, "mh", 16, n_scans=3, runner=_run_unified)
    for name, x, y in zip(("Z", "active", "ZtZ", "ZtX", "m", "n_refresh"),
                          a, b):
        np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y),
            err_msg=f"{name} diverged (seed={seed})")
