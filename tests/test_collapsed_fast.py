"""Backend equivalence + drift-monitor tests for the fast collapsed sampler.

The ``backend="fast"`` row step carries (Lt, M, H) across the row scan via
rank-one Cholesky up/downdates + Sherman–Morrison instead of refactorizing
per row (DESIGN.md §12), and — under ``k_live_buckets="on"`` (default) —
runs that carry PACKED to the live K⁺ bucket with G = HHᵀ carried
rank-one (DESIGN.md §14). These tests certify the speedup is not bought
with approximation:

* full sweeps with the fast (and pallas) backend — packed and unpacked —
  reproduce the O(K^3) oracle's accept decisions on a fixed seed grid —
  same PRNG keys, same chain. A tiny mismatch budget (<=2 bits per run)
  absorbs measure-zero likelihood-boundary events where the two float
  paths may legitimately round an accept differently; a broken carry
  diverges by hundreds of bits within a sweep.
* forced bucket-boundary crossings (births overflowing the block
  mid-sweep -> repack up + resume; post-burn-in deaths -> repack down)
  stay on the oracle's trajectory: bucket repack is a pure permutation +
  refresh.
* the drift monitor actually triggers refreshes when told to distrust the
  carry (tight tolerance) and stays quiet when the carry is healthy, and
  a monitor-repaired chain still matches the oracle.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.ibp import IBPHypers, collapsed_sweep, init_state
from repro.core.ibp.collapsed import PROBE_EVERY, collapsed_row_scan
from repro.core.ibp import math as ibm
from repro.data import cambridge_data

MISMATCH_BUDGET = 2  # bits per run; boundary events, not drift


@pytest.fixture(scope="module")
def data():
    X, _, _ = cambridge_data(N=100, sigma_n=0.4, seed=3)
    return jnp.asarray(X)


def _run(X, backend, refresh, sweeps, seed, k_live="on", seg_log=None,
         K_max=16, K_init=2, alpha=3.0, st=None):
    hyp = IBPHypers()
    if st is None:
        st = init_state(jax.random.key(seed), X.shape[0], X.shape[1],
                        K_max=K_max, K_init=K_init, alpha=alpha)
    for _ in range(sweeps):
        st = collapsed_sweep(st, X, hyp, backend=backend,
                             refresh_every=refresh,
                             k_live_buckets=k_live, seg_log=seg_log)
    return st


def _mismatch(a, b):
    return int(jnp.sum(a.Z * a.active[None, :] != b.Z * b.active[None, :]))


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
@pytest.mark.parametrize("refresh", [8, 32])
def test_fast_sweep_matches_oracle_sweep(data, seed, refresh):
    a = _run(data, "ref", refresh, sweeps=5, seed=seed)
    b = _run(data, "fast", refresh, sweeps=5, seed=seed)
    mism = _mismatch(a, b)
    assert mism <= MISMATCH_BUDGET, f"{mism} bits diverged (seed={seed})"
    assert np.isclose(float(a.sigma_x), float(b.sigma_x), rtol=1e-3)
    assert np.isclose(float(a.alpha), float(b.alpha), rtol=1e-3)
    assert int(a.active.sum()) == int(b.active.sum())


@pytest.mark.parametrize("seed", [0, 2])
def test_unpacked_fast_sweep_matches_oracle_sweep(data, seed):
    """k_live_buckets="off" (the pre-packing carry) stays certified too."""
    a = _run(data, "ref", 8, sweeps=5, seed=seed)
    b = _run(data, "fast", 8, sweeps=5, seed=seed, k_live="off")
    assert _mismatch(a, b) <= MISMATCH_BUDGET
    assert np.isclose(float(a.sigma_x), float(b.sigma_x), rtol=1e-3)


def test_pallas_sweep_matches_oracle_sweep(data):
    a = _run(data, "ref", 16, sweeps=3, seed=0)
    b = _run(data, "pallas", 16, sweeps=3, seed=0)
    mism = _mismatch(a, b)
    assert mism <= MISMATCH_BUDGET, f"{mism} bits diverged"
    assert np.isclose(float(a.sigma_x), float(b.sigma_x), rtol=1e-3)


def test_packed_sweep_bitwise_across_bucket_growth():
    """Cold start on rich data with a high alpha: births overflow the
    8-bucket MID-SWEEP, forcing repack-up + resume — decisions must stay
    on the oracle's trajectory through every crossing."""
    rng = np.random.default_rng(0)
    Zt = (rng.random((120, 12)) < 0.4).astype(np.float32)
    At = rng.standard_normal((12, 24)).astype(np.float32) * 1.5
    X = jnp.asarray(Zt @ At + 0.3 * rng.standard_normal(
        (120, 24)).astype(np.float32))
    a = _run(X, "ref", 8, sweeps=4, seed=0, K_max=32, K_init=1, alpha=8.0)
    seg = []
    b = _run(X, "fast", 8, sweeps=4, seed=0, K_max=32, K_init=1, alpha=8.0,
             seg_log=seg)
    assert _mismatch(a, b) <= MISMATCH_BUDGET, seg
    buckets = {s[0] for s in seg}
    assert len(buckets) >= 2, f"no bucket crossing exercised: {seg}"
    assert any(row > 0 for _, row in seg), \
        f"no MID-sweep overflow repack exercised: {seg}"
    assert int(a.active.sum()) == int(b.active.sum())


def test_packed_sweep_bitwise_across_bucket_shrink(data):
    """Post-burn-in deaths drop occupancy below the bucket: the next
    sweep repacks DOWN (reusing its boundary refactorization) and must
    match the oracle from the same state."""
    hyp = IBPHypers()
    st = init_state(jax.random.key(2), data.shape[0], data.shape[1],
                    K_max=32, K_init=12)
    seg = []
    for _ in range(2):
        st = collapsed_sweep(st, data, hyp, backend="fast",
                             refresh_every=8, seg_log=seg)
    assert seg[0][0] == 16  # 12 live + headroom -> the 16 bucket
    # deaths after burn-in: keep only the first 3 live columns (the
    # driver-level shrink scenario), then compare ref vs packed from the
    # SAME reduced state
    act = np.asarray(st.active)
    keep = np.zeros_like(act)
    keep[np.flatnonzero(act > 0.5)[:3]] = 1.0
    keep_j = jnp.asarray(keep)
    st2 = dataclasses.replace(
        st, Z=st.Z * keep_j[None, :], active=st.active * keep_j)
    a = _run(data, "ref", 8, sweeps=2, seed=0, st=st2)
    seg2 = []
    b = _run(data, "fast", 8, sweeps=2, seed=0, seg_log=seg2, st=st2)
    assert seg2[0][0] == 8, f"bucket did not shrink: {seg2}"
    assert _mismatch(a, b) <= MISMATCH_BUDGET
    assert int(a.active.sum()) == int(b.active.sum())


def test_scan_pack_matches_ref_decisions(data):
    """The in-jit packed entry (pack=True — the hybrid tail's route, full
    width + carried G) reproduces the oracle scan's decisions."""
    N = data.shape[0]
    args = _scan_kwargs(data)
    Zr, ar, *_ = collapsed_row_scan(*args, N=float(N), backend="ref")
    Zp, ap, *_ = collapsed_row_scan(*args, N=float(N), backend="fast",
                                    pack=True)
    mism = int(jnp.sum(Zr * ar[None, :] != Zp * ap[None, :]))
    assert mism <= MISMATCH_BUDGET, mism


def _scan_kwargs(X, seed=0, K_max=12):
    N, D = X.shape
    rng_key = jax.random.key(seed)
    st = init_state(rng_key, N, D, K_max=K_max, K_init=3)
    Z, active = st.Z, st.active
    m = jnp.sum(Z * active[None, :], axis=0)
    ZtZ = (Z.T @ Z) * ibm.mask_outer(active)
    ZtX = (Z.T @ X) * active[:, None]
    return (Z, active, ZtZ, ZtX, m, X, jax.random.fold_in(rng_key, 7),
            st.alpha, st.sigma_x, st.sigma_a)


def test_ref_backend_reports_zero_refreshes(data):
    args = _scan_kwargs(data)
    *_, n_refresh, _ = collapsed_row_scan(*args, N=float(data.shape[0]),
                                          backend="ref")
    assert int(n_refresh) == 0


def test_drift_monitor_triggers_refresh_when_distrusted(data):
    """With a refresh cadence longer than the scan and an impossible drift
    tolerance, every probed row must force a monitor refresh; with a sane
    tolerance the cadence alone accounts for (almost) all refreshes."""
    N = data.shape[0]
    args = _scan_kwargs(data)

    # cadence-only baseline: huge tolerance, cadence 25 -> ~N/25 refreshes
    *_, n_cadence, _ = collapsed_row_scan(
        *args, N=float(N), backend="fast", refresh_every=25, drift_tol=1e9)
    assert int(n_cadence) == N // 25, int(n_cadence)

    # distrust the carry completely: every probed row triggers
    *_, n_forced, _ = collapsed_row_scan(
        *args, N=float(N), backend="fast", refresh_every=10**6,
        drift_tol=0.0)
    assert int(n_forced) >= N // PROBE_EVERY, int(n_forced)

    # healthy carry, no cadence: the monitor stays quiet over a short scan
    *_, n_quiet, _ = collapsed_row_scan(
        *args, N=float(N), backend="fast", refresh_every=10**6,
        drift_tol=1e-2)
    assert int(n_quiet) <= 2, int(n_quiet)


def test_drift_monitor_works_under_pack(data):
    """The packed scan carries the same probe monitor (extended with the
    G-consistency residual): distrusting the carry forces refreshes at
    the probe cadence; a healthy packed carry stays quiet."""
    N = data.shape[0]
    args = _scan_kwargs(data)
    *_, n_forced, _ = collapsed_row_scan(
        *args, N=float(N), backend="fast", refresh_every=10**6,
        drift_tol=0.0, pack=True)
    assert int(n_forced) >= N // PROBE_EVERY, int(n_forced)
    *_, n_quiet, _ = collapsed_row_scan(
        *args, N=float(N), backend="fast", refresh_every=10**6,
        drift_tol=1e-2, pack=True)
    assert int(n_quiet) <= 2, int(n_quiet)


def test_monitor_repaired_chain_still_matches_oracle(data):
    """Forcing monitor refreshes must leave the chain on the oracle's
    trajectory (a refresh is exact, so MORE refreshes can only help)."""
    hyp = IBPHypers()
    a = _run(data, "ref", 8, sweeps=3, seed=5)
    st = init_state(jax.random.key(5), data.shape[0], data.shape[1],
                    K_max=16, K_init=2)
    for _ in range(3):
        st = collapsed_sweep(st, data, hyp, backend="fast", refresh_every=2)
    mism = int(jnp.sum(a.Z * a.active[None, :] != st.Z * st.active[None, :]))
    assert mism <= MISMATCH_BUDGET, mism


def test_packed_scan_uniform_chunking_is_bitwise(data):
    """The hoisted per-row uniform buffer is generated block-wise
    (U_CHUNK_ROWS at a time) for large serial N — the key chain is
    positional, so every chunk size must reproduce the identical
    bitstream, hence identical decisions AND identical carry-out key."""
    from repro.core.ibp.collapsed import _packed_scan

    N = data.shape[0]
    args = _scan_kwargs(data, seed=3)

    def norm(leaf):
        if hasattr(leaf, "dtype") and jax.dtypes.issubdtype(
                leaf.dtype, jax.dtypes.prng_key):
            return np.asarray(jax.random.key_data(leaf))
        return np.asarray(leaf)

    outs = {}
    for chunk in (3, 16, 4096):
        out = _packed_scan(*args, 0, N=float(N), birth="gibbs", B=8,
                           refresh_every=64, u_chunk_rows=chunk)
        outs[chunk] = [norm(x) for x in out]
    for chunk in (16, 4096):
        for a, b in zip(outs[3], outs[chunk]):
            np.testing.assert_array_equal(a, b)


def test_packed_resume_bitwise_at_chunk_boundary():
    """Satellite regression: the overflow-repack resume re-reads its
    uniforms POSITIONALLY — when the overflow row lands exactly on a
    u_chunk_rows boundary (the resumed row's draw sits at the first slot
    of a refilled block, and the overflowing attempt itself triggered
    the refill), the chunked re-read must be bitwise identical to the
    unchunked hoist. The chunk sizes are derived from the actual
    overflow rows so each resume start IS a block boundary."""
    from repro.core.ibp.collapsed import (PACK_HEADROOM, _packed_scan,
                                          _sweep_stats)

    rng = np.random.default_rng(0)
    Zt = (rng.random((120, 12)) < 0.4).astype(np.float32)
    At = rng.standard_normal((12, 24)).astype(np.float32) * 1.5
    X = jnp.asarray(Zt @ At
                    + 0.3 * rng.standard_normal((120, 24)).astype(np.float32))
    N = 120
    st = init_state(jax.random.key(0), N, 24, K_max=32, K_init=1, alpha=8.0)
    buckets = ibm.live_buckets(32)

    def sweep(u_chunk):
        m, ZtZ, ZtX, kp = _sweep_stats(st.Z, st.active, X)
        Z, active = st.Z, st.active
        key = jax.random.fold_in(st.key, 1)
        row, segs = 0, []
        kp = int(kp)
        while row < N:
            B = ibm.pick_bucket(buckets, kp, PACK_HEADROOM)
            segs.append((B, row))
            Z, active, ZtZ, ZtX, m, _, _, key, ovf_row = _packed_scan(
                Z, active, ZtZ, ZtX, m, X, key, st.alpha, st.sigma_x,
                st.sigma_a, row, N=float(N), birth="gibbs", B=B,
                refresh_every=8, u_chunk_rows=u_chunk)
            ovf, kp = map(int, jax.device_get((ovf_row, jnp.sum(active))))
            row = N if ovf < 0 else ovf
        return segs, (Z, active, ZtZ, ZtX, m)

    segs_ref, out_ref = sweep(4096)          # one block covers every segment
    starts = [r for _, r in segs_ref if r > 0]
    assert starts, "setup no longer overflows mid-sweep; rechoose data"
    # chunk sizes that put each resume start exactly on a block boundary
    chunks = sorted({r for r in starts}
                    | {b - a for a, b in zip(starts, starts[1:]) if b > a})
    for c in chunks:
        segs_c, out_c = sweep(c)
        assert segs_c == segs_ref, (c, segs_c, segs_ref)
        for name, x, y in zip(("Z", "active", "ZtZ", "ZtX", "m"),
                              out_ref, out_c):
            np.testing.assert_array_equal(
                np.asarray(x), np.asarray(y),
                err_msg=f"{name} diverged at u_chunk_rows={c}")
