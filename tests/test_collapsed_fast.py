"""Backend equivalence + drift-monitor tests for the fast collapsed sampler.

The ``backend="fast"`` row step carries (Lt, M, H) across the row scan via
rank-one Cholesky up/downdates + Sherman–Morrison instead of refactorizing
per row (DESIGN.md §12). These tests certify the speedup is not bought
with approximation:

* full sweeps with the fast (and pallas) backend reproduce the O(K^3)
  oracle's accept decisions on a fixed seed grid — same PRNG keys, same
  chain. A tiny mismatch budget (<=2 bits per run) absorbs measure-zero
  likelihood-boundary events where the two float paths may legitimately
  round an accept differently; a broken carry diverges by hundreds of
  bits within a sweep.
* the drift monitor actually triggers refreshes when told to distrust the
  carry (tight tolerance) and stays quiet when the carry is healthy, and
  a monitor-repaired chain still matches the oracle.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.ibp import IBPHypers, collapsed_sweep, init_state
from repro.core.ibp.collapsed import PROBE_EVERY, collapsed_row_scan
from repro.core.ibp import math as ibm
from repro.data import cambridge_data

MISMATCH_BUDGET = 2  # bits per run; boundary events, not drift


@pytest.fixture(scope="module")
def data():
    X, _, _ = cambridge_data(N=100, sigma_n=0.4, seed=3)
    return jnp.asarray(X)


def _run(X, backend, refresh, sweeps, seed):
    hyp = IBPHypers()
    st = init_state(jax.random.key(seed), X.shape[0], X.shape[1],
                    K_max=16, K_init=2)
    for _ in range(sweeps):
        st = collapsed_sweep(st, X, hyp, backend=backend,
                             refresh_every=refresh)
    return st


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
@pytest.mark.parametrize("refresh", [8, 32])
def test_fast_sweep_matches_oracle_sweep(data, seed, refresh):
    a = _run(data, "ref", refresh, sweeps=5, seed=seed)
    b = _run(data, "fast", refresh, sweeps=5, seed=seed)
    mism = int(jnp.sum(a.Z * a.active[None, :] != b.Z * b.active[None, :]))
    assert mism <= MISMATCH_BUDGET, f"{mism} bits diverged (seed={seed})"
    assert np.isclose(float(a.sigma_x), float(b.sigma_x), rtol=1e-3)
    assert np.isclose(float(a.alpha), float(b.alpha), rtol=1e-3)
    assert int(a.active.sum()) == int(b.active.sum())


def test_pallas_sweep_matches_oracle_sweep(data):
    a = _run(data, "ref", 16, sweeps=3, seed=0)
    b = _run(data, "pallas", 16, sweeps=3, seed=0)
    mism = int(jnp.sum(a.Z * a.active[None, :] != b.Z * b.active[None, :]))
    assert mism <= MISMATCH_BUDGET, f"{mism} bits diverged"
    assert np.isclose(float(a.sigma_x), float(b.sigma_x), rtol=1e-3)


def _scan_kwargs(X, seed=0, K_max=12):
    N, D = X.shape
    rng_key = jax.random.key(seed)
    st = init_state(rng_key, N, D, K_max=K_max, K_init=3)
    Z, active = st.Z, st.active
    m = jnp.sum(Z * active[None, :], axis=0)
    ZtZ = (Z.T @ Z) * ibm.mask_outer(active)
    ZtX = (Z.T @ X) * active[:, None]
    return (Z, active, ZtZ, ZtX, m, X, jax.random.fold_in(rng_key, 7),
            st.alpha, st.sigma_x, st.sigma_a)


def test_ref_backend_reports_zero_refreshes(data):
    args = _scan_kwargs(data)
    *_, n_refresh = collapsed_row_scan(*args, N=float(data.shape[0]),
                                       backend="ref")
    assert int(n_refresh) == 0


def test_drift_monitor_triggers_refresh_when_distrusted(data):
    """With a refresh cadence longer than the scan and an impossible drift
    tolerance, every probed row must force a monitor refresh; with a sane
    tolerance the cadence alone accounts for (almost) all refreshes."""
    N = data.shape[0]
    args = _scan_kwargs(data)

    # cadence-only baseline: huge tolerance, cadence 25 -> ~N/25 refreshes
    *_, n_cadence = collapsed_row_scan(
        *args, N=float(N), backend="fast", refresh_every=25, drift_tol=1e9)
    assert int(n_cadence) == N // 25, int(n_cadence)

    # distrust the carry completely: every probed row triggers
    *_, n_forced = collapsed_row_scan(
        *args, N=float(N), backend="fast", refresh_every=10**6,
        drift_tol=0.0)
    assert int(n_forced) >= N // PROBE_EVERY, int(n_forced)

    # healthy carry, no cadence: the monitor stays quiet over a short scan
    *_, n_quiet = collapsed_row_scan(
        *args, N=float(N), backend="fast", refresh_every=10**6,
        drift_tol=1e-2)
    assert int(n_quiet) <= 2, int(n_quiet)


def test_monitor_repaired_chain_still_matches_oracle(data):
    """Forcing monitor refreshes must leave the chain on the oracle's
    trajectory (a refresh is exact, so MORE refreshes can only help)."""
    hyp = IBPHypers()
    a = _run(data, "ref", 8, sweeps=3, seed=5)
    st = init_state(jax.random.key(5), data.shape[0], data.shape[1],
                    K_max=16, K_init=2)
    for _ in range(3):
        st = collapsed_sweep(st, data, hyp, backend="fast", refresh_every=2)
    mism = int(jnp.sum(a.Z * a.active[None, :] != st.Z * st.active[None, :]))
    assert mism <= MISMATCH_BUDGET, mism
