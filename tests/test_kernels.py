"""Per-kernel allclose vs pure-jnp oracle: shape & dtype sweeps + property
tests (interpret=True executes the Pallas body on CPU). Property tests use
hypothesis when installed, else a fixed-seed parametrized fallback
(tests/_hyp_compat.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp_compat import given_or_params

from repro.kernels.collapsed_row import (
    collapsed_row_flip,
    collapsed_row_flip_fast,
    collapsed_row_flip_ref,
)
from repro.kernels.feature_stats import feature_stats, feature_stats_ref
from repro.kernels.gaussian_sse import gaussian_sse, gaussian_sse_ref
from repro.kernels.gibbs_flip import gibbs_flip_core, gibbs_flip_ref

SHAPES = [(16, 8, 4), (100, 36, 16), (257, 64, 8), (64, 128, 32), (33, 20, 5)]


def _inputs(N, D, K, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.standard_normal((N, D)), dtype)
    Z = jnp.asarray((rng.random((N, K)) < 0.3), dtype)
    A = jnp.asarray(rng.standard_normal((K, D)), dtype)
    act = jnp.asarray((rng.random(K) < 0.8), dtype)
    return X, Z, A, act, rng


@pytest.mark.parametrize("N,D,K", SHAPES)
@pytest.mark.parametrize("block_n", [32, 128])
def test_gibbs_flip_matches_ref(N, D, K, block_n):
    X, Z, A, act, rng = _inputs(N, D, K)
    lpi = jnp.asarray(rng.standard_normal(K), jnp.float32)
    u = jnp.asarray(rng.standard_normal((N, K)) * 2, jnp.float32)
    inv2s2 = jnp.float32(0.5)
    got = gibbs_flip_core(X, Z, A, lpi, act, u, inv2s2, block_n=block_n)
    want = gibbs_flip_ref(X, Z, A, lpi, act, u, inv2s2)
    assert jnp.all(got == want), f"mismatch at {(N, D, K, block_n)}"


@pytest.mark.parametrize("N,D,K", SHAPES)
def test_feature_stats_matches_ref(N, D, K):
    X, Z, _, _, _ = _inputs(N, D, K)
    ztz, ztx, m = feature_stats(X, Z, block_n=64)
    ztz_r, ztx_r, m_r = feature_stats_ref(X, Z)
    np.testing.assert_allclose(ztz, ztz_r, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(ztx, ztx_r, rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(m, m_r)


@pytest.mark.parametrize("N,D,K", SHAPES)
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_gaussian_sse_matches_ref(N, D, K, dtype):
    X, Z, A, act, _ = _inputs(N, D, K, dtype=dtype)
    got = gaussian_sse(X, Z, A, act, block_n=64)
    want = gaussian_sse_ref(X, Z, A, act)
    rtol = 1e-5 if dtype == np.float32 else 2e-2
    np.testing.assert_allclose(float(got), float(want), rtol=rtol)


# ---------------------------------------------------------------------------
# collapsed_row: the K-sequential collapsed bit-flip recurrence
# ---------------------------------------------------------------------------


def _collapsed_row_inputs(K, D, seed=0, frac_active=1.0):
    rng = np.random.default_rng(seed)
    act = (rng.random(K) < frac_active).astype(np.float32)
    if act.sum() == 0:
        act[0] = 1.0
    Zb = ((rng.random((5 * K, K)) < 0.3) * act).astype(np.float32)
    W = Zb.T @ Zb + 0.7 * np.diag(act) + np.diag(1 - act)
    M = (np.linalg.inv(W) * np.outer(act, act)).astype(np.float32)
    ZtX = (Zb.T @ rng.standard_normal((5 * K, D))).astype(np.float32)
    H = (M @ ZtX).astype(np.float32)
    x = rng.standard_normal(D).astype(np.float32)
    z = ((rng.random(K) < 0.4) * act).astype(np.float32)
    v = (M @ z).astype(np.float32)
    q = np.float32(z @ v)
    mean = (z @ H).astype(np.float32)
    u = (rng.standard_normal(K) * 2).astype(np.float32)
    mm = Zb.sum(0).astype(np.float32)
    args = [jnp.asarray(a) for a in (M, H, x, z, v, q, mean, u, mm, act)]
    return args + [jnp.float32(8 * K), jnp.float32(0.5)]


@pytest.mark.parametrize("K,D", [(8, 16), (16, 36), (64, 64), (5, 7),
                                 (12, 128)])
def test_collapsed_row_pallas_matches_ref_bitwise(K, D):
    args = _collapsed_row_inputs(K, D, seed=K + D)
    zr, vr, qr, mr = collapsed_row_flip_ref(*args)
    zp, vp, qp, mp = collapsed_row_flip(*args, flavor="pallas")
    assert jnp.all(zr == zp), "pallas decisions diverge from the jnp oracle"
    np.testing.assert_array_equal(np.asarray(vr), np.asarray(vp))
    assert float(qr) == float(qp)
    np.testing.assert_array_equal(np.asarray(mr), np.asarray(mp))


@given_or_params(max_examples=20, k=(2, 24), d=(2, 48), seed=(0, 10_000))
def test_collapsed_row_fast_matches_ref_under_padding(k, d, seed):
    """The packed-active rss/rH flavor must reproduce the oracle's
    decisions and carried quadratics (different float path, so the
    continuous outputs get a tolerance; decisions are compared exactly
    on this fixed-seed grid)."""
    rng = np.random.default_rng(seed)
    args = _collapsed_row_inputs(k, d, seed=seed,
                                 frac_active=float(rng.uniform(0.3, 1.0)))
    zr, vr, qr, mr = collapsed_row_flip_ref(*args)
    zf, vf, qf, mf = collapsed_row_flip_fast(*args)
    np.testing.assert_array_equal(np.asarray(zr), np.asarray(zf))
    np.testing.assert_allclose(np.asarray(vr), np.asarray(vf),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(float(qr), float(qf), rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(mr), np.asarray(mf),
                               rtol=1e-3, atol=1e-3)
    # inactive columns must never flip
    inact = np.asarray(args[9]) < 0.5
    np.testing.assert_array_equal(np.asarray(zf)[inact],
                                  np.asarray(args[3])[inact])


# ---------------------------------------------------------------------------
# property-based: invariants of the Gibbs-flip kernel
# ---------------------------------------------------------------------------


@given_or_params(max_examples=20, n=(5, 70), d=(2, 40), k=(1, 12),
                 seed=(0, 10_000))
def test_gibbs_flip_property_binary_and_active_respected(n, d, k, seed):
    X, Z, A, act, rng = _inputs(n, d, k, seed=seed)
    lpi = jnp.asarray(rng.standard_normal(k), jnp.float32)
    u = jnp.asarray(rng.standard_normal((n, k)) * 2, jnp.float32)
    out = gibbs_flip_core(X, Z, A, lpi, act, u, jnp.float32(0.5), block_n=32)
    out_np = np.asarray(out)
    # output is binary
    assert set(np.unique(out_np)).issubset({0.0, 1.0})
    # inactive columns unchanged
    inactive = np.asarray(act) < 0.5
    np.testing.assert_array_equal(out_np[:, inactive], np.asarray(Z)[:, inactive])
    # kernel == oracle everywhere (the strongest property)
    want = np.asarray(gibbs_flip_ref(X, Z, A, lpi, act, u, jnp.float32(0.5)))
    np.testing.assert_array_equal(out_np, want)


@given_or_params(max_examples=20, n=(5, 60), d=(2, 30), k=(1, 10),
                 seed=(0, 10_000))
def test_feature_stats_property_psd_and_counts(n, d, k, seed):
    X, Z, _, _, _ = _inputs(n, d, k, seed=seed)
    ztz, ztx, m = feature_stats(X, Z, block_n=32)
    # ZtZ is PSD with diagonal = column counts = m
    np.testing.assert_allclose(np.diag(np.asarray(ztz)), np.asarray(m))
    evals = np.linalg.eigvalsh(np.asarray(ztz))
    assert evals.min() > -1e-4
    # m bounded by N
    assert np.all(np.asarray(m) <= n)


@given_or_params(max_examples=20, n=(5, 60), d=(2, 30), k=(1, 10),
                 seed=(0, 10_000))
def test_gaussian_sse_property_nonneg_and_zero_residual(n, d, k, seed):
    X, Z, A, act, _ = _inputs(n, d, k, seed=seed)
    s = gaussian_sse(X, Z, A, act, block_n=32)
    assert float(s) >= 0
    # exact-zero residual case
    X2 = (Z * act[None, :]) @ A
    s2 = gaussian_sse(X2, Z, A, act, block_n=32)
    assert float(s2) < 1e-3 * max(1.0, float(jnp.sum(X2 * X2)))
