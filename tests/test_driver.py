"""MCMCDriver backend/knob coverage: the K_max-overflow checkpoint-and-grow
restart, the bounded-staleness knob, multichain checkpoint/resume
(bitwise), and diagnostics in eval records."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step
from repro.core.ibp import IBPHypers
from repro.data import cambridge_data
from repro.runtime import DriverConfig, MCMCDriver


@pytest.fixture(scope="module")
def data():
    X, _, _ = cambridge_data(N=48, sigma_n=0.4, seed=3)
    return X


def test_kmax_overflow_checkpoints_then_grows(data, tmp_path):
    """Feature-slot overflow checkpoints + raises; restarting with a larger
    K_max pads the checkpointed feature axis and resumes (never silent
    truncation) — DESIGN.md §10."""
    cfg = DriverConfig(P=3, K_max=2, K_tail=2, K_init=1, L=3, n_iters=40,
                      ckpt_every=1000, eval_every=1000,
                      ckpt_dir=str(tmp_path))
    with pytest.raises(RuntimeError, match="overflow"):
        MCMCDriver(data, cfg, IBPHypers()).run()
    step = latest_step(str(tmp_path))
    assert step is not None  # overflow wrote a checkpoint first

    # grow-and-restart until the run completes (capacity doubles each time)
    K = cfg.K_max
    for _ in range(4):
        K *= 2
        try:
            gs, ss = MCMCDriver(
                data, dataclasses.replace(cfg, K_max=K), IBPHypers()
            ).run()
            break
        except RuntimeError:
            continue
    else:
        pytest.fail("growth never reached sufficient capacity")
    assert int(gs.it) == 40
    assert ss.Z.shape[-1] == K            # feature axis actually grew
    assert int(jnp.max(gs.overflow)) == 0
    assert int(gs.active.sum()) >= 1


def test_kmax_shrink_restart_compacts_features(data, tmp_path):
    """Restoring a checkpoint under a SMALLER K_max compacts the live
    features (plus lowest free slots — the packed-carry block rule) into
    the new capacity and resumes; an impossible shrink refuses loudly
    (DESIGN.md §14)."""
    cfg = DriverConfig(P=3, K_max=16, K_tail=4, K_init=3, L=2, n_iters=6,
                       ckpt_every=3, eval_every=1000,
                       ckpt_dir=str(tmp_path))
    gs, ss = MCMCDriver(data, cfg, IBPHypers()).run()
    n_live = int(gs.active.sum())
    assert 1 <= n_live, "need live features to exercise the shrink"
    K_small = max(6, n_live)
    if K_small >= cfg.K_max:
        pytest.skip(f"chain kept {n_live} live features; nothing to shrink")
    gs2, ss2 = MCMCDriver(
        data, dataclasses.replace(cfg, K_max=K_small, n_iters=10),
        IBPHypers(),
    ).run()
    assert ss2.Z.shape[-1] == K_small      # feature axis actually shrank
    assert int(gs2.it) == 10               # and the run resumed + finished
    assert int(gs2.active.sum()) >= 1
    # refusing case: capacity below the live set must fail loudly, never
    # silently truncate (restores the latest — post-shrink-run — ckpt)
    n_live2 = int(gs2.active.sum())
    if n_live2 >= 2:
        with pytest.raises(ValueError, match="shrink"):
            MCMCDriver(
                data,
                dataclasses.replace(
                    cfg, K_max=n_live2 - 1, K_init=1, K_tail=2),
                IBPHypers(),
            ).run()


def test_stale_sync_knob_runs_and_differs(data, tmp_path):
    """stale_sync > 0 interleaves sync-free sub-iteration passes: the run
    stays finite/sane but takes a different (non-exact) trajectory."""
    mk = lambda sub, s: DriverConfig(
        P=3, K_max=12, K_tail=6, L=2, n_iters=8, ckpt_every=1000,
        eval_every=1000, stale_sync=s, ckpt_dir=str(tmp_path / sub))
    gs0, _ = MCMCDriver(data, mk("a", 0), IBPHypers()).run()
    gs2, _ = MCMCDriver(data, mk("b", 2), IBPHypers()).run()
    assert np.isfinite(float(gs2.sigma_x))
    assert 1 <= int(gs2.active.sum()) <= 12
    # the stale trajectory consumed different randomness -> different state
    assert float(gs0.sigma_x) != float(gs2.sigma_x)


def test_stale_pass_key_advance_distinct_from_consumed_stream(data):
    """Regression pin: the key a stale pass hands forward (fold 14) must
    differ from the key its sweeps consumed (fold 13) — otherwise the next
    iteration's sub-iterations replay the same per-(shard, l) uniforms."""
    from repro.core.ibp import SamplerSpec, build_sampler

    s = build_sampler(SamplerSpec(P=3, K_max=12, K_tail=6, K_init=3, L=2),
                      IBPHypers(), data)
    gs, st = s.init(jax.random.key(0))
    gs2, _ = s.stale(gs, st)
    kd = lambda k: np.asarray(jax.random.key_data(k))
    assert not np.array_equal(kd(gs2.key),
                              kd(jax.random.fold_in(gs.key, 13)))
    np.testing.assert_array_equal(kd(gs2.key),
                                  kd(jax.random.fold_in(gs.key, 14)))


def test_stale_pass_shardmap_matches_vmap(data):
    """The collective-free shard_map stale pass is bitwise-equivalent to
    the vmap stale pass (P=1 mesh runs in-process on one device)."""
    from repro.core.ibp import SamplerSpec, build_sampler

    spec = SamplerSpec(P=1, K_max=12, K_tail=6, K_init=3, L=2)
    sv = build_sampler(spec, IBPHypers(), data)
    sm = build_sampler(spec.replace(data="shardmap"), IBPHypers(), data)
    gs, st_v = sv.init(jax.random.key(4))
    st_m = sm.from_canonical(sv.to_canonical(st_v))  # identical start
    gs_v, ss_v = sv.stale(gs, st_v)
    gs_s, ss_s = sm.stale(gs, st_m)
    np.testing.assert_array_equal(np.asarray(sv.to_canonical(ss_v).Z),
                                  np.asarray(sm.to_canonical(ss_s).Z))
    np.testing.assert_array_equal(
        np.asarray(jax.random.key_data(gs_v.key)),
        np.asarray(jax.random.key_data(gs_s.key)))


def test_multichain_resumes_bitwise_from_checkpoint(data, tmp_path):
    """Straight-through multichain run == crash/resume run, bitwise, for
    every chain (the checkpoint carries the per-chain keys)."""
    mk = lambda sub, n: DriverConfig(
        P=3, K_max=12, K_tail=6, L=3, n_iters=n, ckpt_every=5,
        eval_every=100, driver="multichain", n_chains=3,
        ckpt_dir=str(tmp_path / sub))
    gs_a, ss_a = MCMCDriver(data, mk("full", 10), IBPHypers()).run()
    MCMCDriver(data, mk("half", 5), IBPHypers()).run()
    gs_b, ss_b = MCMCDriver(data, mk("half", 10), IBPHypers()).run()
    np.testing.assert_array_equal(np.asarray(ss_a.Z), np.asarray(ss_b.Z))
    np.testing.assert_array_equal(np.asarray(gs_a.sigma_x),
                                  np.asarray(gs_b.sigma_x))
    np.testing.assert_array_equal(np.asarray(gs_a.A), np.asarray(gs_b.A))
    np.testing.assert_array_equal(
        np.asarray(jax.random.key_data(gs_a.key)),
        np.asarray(jax.random.key_data(gs_b.key)))


def test_multichain_eval_records_diagnostics(data, tmp_path):
    """C >= 4 vectorized chains advance in one jitted step and eval
    records carry split-R-hat / ESS / MCSE plus per-chain stats."""
    cfg = DriverConfig(P=3, K_max=12, K_tail=6, L=3, n_iters=16,
                      ckpt_every=1000, eval_every=8, driver="multichain",
                      n_chains=4, ckpt_dir=str(tmp_path))
    drv = MCMCDriver(data, cfg, IBPHypers())
    gs, ss = drv.run()
    assert ss.Z.shape[0] == 4             # chain axis
    rec = drv.history[-1]
    for k in ("sigma_x_rhat", "sigma_x_ess", "sigma_x_mcse", "K_rhat"):
        assert k in rec, rec.keys()
    assert len(rec["K_chains"]) == 4
    assert len(rec["sigma_x_chains"]) == 4
    # chains are genuinely independent: distinct trajectories
    assert len({round(s, 6) for s in rec["sigma_x_chains"]}) > 1
    # trace has one (C,) row per iteration
    assert len(drv.trace["sigma_x"]) == 16
    assert drv.trace["sigma_x"][0].shape == (4,)


def test_checkpoint_interchange_vmap_to_multichain_rejected(data, tmp_path):
    """A single-chain checkpoint cannot silently restore under a
    chain-batched template — leaf shapes disagree loudly."""
    cfg = DriverConfig(P=3, K_max=12, K_tail=6, L=2, n_iters=4,
                      ckpt_every=2, eval_every=100, ckpt_dir=str(tmp_path))
    MCMCDriver(data, cfg, IBPHypers()).run()
    cfg_mc = dataclasses.replace(cfg, driver="multichain", n_chains=2,
                                 n_iters=6)
    with pytest.raises(ValueError, match="chain"):
        MCMCDriver(data, cfg_mc, IBPHypers()).run()


def test_multichain_resume_rejects_changed_chain_count(data, tmp_path):
    """n_chains is part of the checkpointed state: resuming with a
    different chain count fails loudly instead of silently keeping the
    old C while diagnostics claim the new one."""
    mk = lambda c, n: DriverConfig(
        P=3, K_max=12, K_tail=6, L=2, n_iters=n, ckpt_every=2,
        eval_every=100, driver="multichain", n_chains=c,
        ckpt_dir=str(tmp_path))
    MCMCDriver(data, mk(3, 4), IBPHypers()).run()
    with pytest.raises(ValueError, match="n_chains"):
        MCMCDriver(data, mk(8, 8), IBPHypers()).run()


def test_adaptive_k_tail_grows_on_saturation(tmp_path):
    """k_tail_grow > 0: tail saturation (capacity-vetoed accepted MH
    births, gs.tail_sat) at a checkpoint boundary doubles K_tail
    in-process — the run continues with wider tail buffers, the ceiling
    is K_max, and eval records surface K_tail + tail_sat."""
    rng = np.random.default_rng(0)
    Zt = (rng.random((60, 10)) < 0.4).astype(np.float32)
    At = rng.standard_normal((10, 16)).astype(np.float32) * 1.5
    X = Zt @ At + 0.3 * rng.standard_normal((60, 16)).astype(np.float32)
    cfg = DriverConfig(P=3, K_max=16, K_tail=1, K_init=1, L=3, n_iters=30,
                       ckpt_every=5, eval_every=10, k_tail_grow=3,
                       alpha=8.0, ckpt_dir=str(tmp_path))
    drv = MCMCDriver(X, cfg, IBPHypers())
    gs, ss = drv.run()
    assert int(gs.it) == 30                       # ran to completion
    assert drv.spec.K_tail > 1                    # growth actually fired
    assert drv.spec.K_tail <= cfg.K_max
    assert ss.Z_tail.shape[-1] == drv.spec.K_tail  # buffers follow the spec
    rec = drv.history[-1]
    assert rec["K_tail"] == drv.spec.K_tail
    assert rec["tail_sat"] >= 0
    assert drv._tail_growths <= cfg.k_tail_grow


def test_k_tail_fixed_when_grow_disabled(data, tmp_path):
    """k_tail_grow=0 (default): saturation may accrue but K_tail never
    moves — the historical fixed-truncation behavior."""
    cfg = DriverConfig(P=3, K_max=12, K_tail=2, K_init=2, L=3, n_iters=12,
                       ckpt_every=4, eval_every=6, alpha=6.0,
                       ckpt_dir=str(tmp_path))
    drv = MCMCDriver(data, cfg, IBPHypers())
    gs, ss = drv.run()
    assert drv.spec.K_tail == 2
    assert ss.Z_tail.shape[-1] == 2
    assert drv.history[-1]["K_tail"] == 2
    assert "tail_sat" in drv.history[-1]
