"""Sampler exactness: the hybrid parallel sampler targets the SAME posterior
as the serial collapsed Gibbs baseline (the paper's central correctness
claim — asymptotically exact, no approximation from parallelism).

We compare posterior summaries (E[K+], E[sigma_x], E[log P(X,Z)]) from long
chains of both samplers on the same small data set, within MC error. These
are distribution-level checks — the chains themselves are different Markov
kernels and need not match pathwise.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.ibp import (
    IBPHypers,
    collapsed_sweep,
    hybrid_iteration_vmap,
    init_hybrid,
    init_state,
)
from repro.core.ibp.diagnostics import train_joint_loglik
from repro.core.ibp import math as ibm
from repro.data import cambridge_data, shard_rows

N, D, K_MAX = 72, 36, 12
BURN, KEEP, THIN = 60, 120, 2


@pytest.fixture(scope="module")
def data():
    X, _, _ = cambridge_data(N=N, sigma_n=0.5, seed=11)
    return X


@pytest.fixture(scope="module")
def collapsed_chain(data):
    X = jnp.asarray(data)
    hyp = IBPHypers()
    st = init_state(jax.random.key(1), N, D, K_MAX, K_init=1)
    Ks, sxs, lls = [], [], []
    for it in range(BURN + KEEP):
        st = collapsed_sweep(st, X, hyp)
        if it >= BURN and (it - BURN) % THIN == 0:
            Ks.append(int(st.k_plus))
            sxs.append(float(st.sigma_x))
            # draw A | Z for the joint ll (collapsed chain carries no A)
            ZtZ = (st.Z.T @ st.Z) * ibm.mask_outer(st.active)
            ZtX = (st.Z.T @ X) * st.active[:, None]
            A, _ = ibm.a_posterior(ZtZ, ZtX, st.active, st.sigma_x,
                                   st.sigma_a)
            m = jnp.sum(st.Z * st.active[None, :], axis=0)
            pi = jnp.clip(m / N, 1e-4, 1 - 1e-4) * st.active
            lls.append(float(train_joint_loglik(X, st.Z, A, pi, st.active,
                                                st.sigma_x)))
    return np.array(Ks), np.array(sxs), np.array(lls)


@pytest.fixture(scope="module")
def hybrid_chain(data):
    P = 3
    Xs = jnp.asarray(shard_rows(data, P))
    X = jnp.asarray(data)
    hyp = IBPHypers()
    gs, ss = init_hybrid(jax.random.key(2), Xs, K_MAX, K_tail=6, K_init=3)
    Ks, sxs, lls = [], [], []
    for it in range(BURN + KEEP):
        gs, ss = hybrid_iteration_vmap(Xs, gs, ss, hyp, L=3, N_global=N)
        if it >= BURN and (it - BURN) % THIN == 0:
            Ks.append(int(jnp.sum(gs.active)))
            sxs.append(float(gs.sigma_x))
            Z = ss.Z.reshape(N, -1)
            lls.append(float(train_joint_loglik(X, Z, gs.A, gs.pi,
                                                gs.active, gs.sigma_x)))
    return np.array(Ks), np.array(sxs), np.array(lls)


def test_posterior_K_agrees(collapsed_chain, hybrid_chain):
    """Both chains find the ~4 true features and agree on E[K+]."""
    Kc, Kh = collapsed_chain[0], hybrid_chain[0]
    assert 3 <= Kc.mean() <= 7, Kc.mean()
    assert 3 <= Kh.mean() <= 7, Kh.mean()
    # MC tolerance: K+ posterior is narrow on this data (alpha log N ~ 4-5)
    assert abs(Kc.mean() - Kh.mean()) < 1.5, (Kc.mean(), Kh.mean())


def test_posterior_sigma_x_agrees(collapsed_chain, hybrid_chain):
    """E[sigma_x] matches the true noise scale (0.5) for both samplers."""
    sc, sh = collapsed_chain[1], hybrid_chain[1]
    assert abs(sc.mean() - 0.5) < 0.08, sc.mean()
    assert abs(sh.mean() - 0.5) < 0.08, sh.mean()
    assert abs(sc.mean() - sh.mean()) < 0.06, (sc.mean(), sh.mean())


def test_posterior_joint_ll_agrees(collapsed_chain, hybrid_chain):
    """Stationary joint log-lik levels agree within a few percent."""
    lc, lh = collapsed_chain[2], hybrid_chain[2]
    rel = abs(lc.mean() - lh.mean()) / abs(lc.mean())
    assert rel < 0.05, (lc.mean(), lh.mean(), rel)


def test_hybrid_is_exact_not_approximate(hybrid_chain):
    """The hybrid chain mixes over K (features born AND die) — evidence the
    tail proposal is live, unlike approximate parallel IBP samplers that
    freeze the feature set between syncs."""
    Ks = hybrid_chain[0]
    assert Ks.std() > 0 or len(np.unique(Ks)) > 1 or Ks.mean() >= 4
