"""Sampler exactness: the hybrid parallel sampler targets the SAME posterior
as the serial collapsed Gibbs baseline (the paper's central correctness
claim — asymptotically exact, no approximation from parallelism).

Statistical design (DESIGN.md §11) — no hard single-chain tolerances:

* posterior summaries are compared via MCSE/ESS-aware z-scores
  (``convergence.mean_diff_z``), with the hybrid side pooled over C=4
  VECTORIZED chains (``chains="vmap"`` sampler layout) so between-chain
  variance is measured, not guessed;
* the joint-ll comparison is draw-vs-draw: the collapsed chain DRAWS
  A ~ p(A|Z,X) and pi ~ Beta(m, 1+N-m) exactly as the hybrid master
  does (a plug-in posterior MEAN would score systematically higher by
  Jensen and fail any honest tolerance);
* mixing is asserted as split-R-hat < 1.05 across the 4 chains;
* a Geweke-style "getting it right" joint-distribution check runs two
  successive-conditional simulators (posterior transition alternated
  with X ~ p(X|theta) regeneration) for the hybrid and collapsed
  kernels and compares their stationary prior-land moments.

Finite-truncation caveat, measured and documented: the two kernels
truncate the IBP tail differently (J_MAX births/row, K_tail in-flight
features, births on p' only vs deaths everywhere), so their
stationary K+ marginals differ by O(1) at test sizes even though both
are asymptotically exact. The K_tail component of that gap is CLOSED:
the hybrid fixture runs the full-width tail (K_tail = K_max — the
state adaptive K_tail growth, DESIGN.md §12, converges to under
saturation), which shrank the posterior K+ gap to ~0.3 and let the
envelopes tighten (see constants below);
test_k_gap_shrinks_as_tail_widens pins that the gap is monotone in
K_tail. What survives at full width is structural — births on p'
only, J_MAX per row — and dominates only in prior-land at tiny N
(the Geweke check keeps its own envelope for exactly that regime).
Comparisons on K carry these explicit truncation envelopes (they
still catch sign/scale regressions, which shift K by far more);
statistics dominated by the likelihood (sigma_x, assignment mass)
get pure z-tests.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.ibp import (
    IBPHypers,
    SamplerSpec,
    build_hybrid_fns,
    build_sampler,
    collapsed_sweep,
    init_hybrid,
    init_state,
)
from repro.core.ibp import convergence as cv
from repro.core.ibp.diagnostics import train_joint_loglik
from repro.core.ibp import math as ibm
from repro.data import cambridge_data

N, D, K_MAX = 72, 36, 12
C_CHAINS = 4
BURN, KEEP, THIN = 200, 600, 2

# measured finite-truncation envelopes (see module docstring): with the
# full-width tail (K_tail = K_max) the stationary K+ gap between the
# two kernels is ~0.3 at these sizes and the coupled joint-ll offset is
# ~2-5 nats (it was ~0.8-1.3 K+ / ~25 nats under the old fixed
# K_tail=6 truncation). A real regression (wrong prior weight, broken
# births, scale error) moves these by multiples.
K_TRUNC_TOL = 0.8
LL_TRUNC_TOL = 20.0
Z_OK = 4.0


@pytest.fixture(scope="module")
def data():
    X, _, _ = cambridge_data(N=N, sigma_n=0.5, seed=11)
    return X


@pytest.fixture(scope="module")
def collapsed_chain(data):
    """Single collapsed chain; (A, pi) DRAWN per kept sample for the ll."""
    X = jnp.asarray(data)
    hyp = IBPHypers()
    st = init_state(jax.random.key(1), N, D, K_MAX, K_init=1)
    key = jax.random.key(100)
    Ks, sxs, lls = [], [], []
    for it in range(BURN + KEEP):
        st = collapsed_sweep(st, X, hyp)
        if it >= BURN and (it - BURN) % THIN == 0:
            key, ka, kp = jax.random.split(key, 3)
            Ks.append(float(st.k_plus))
            sxs.append(float(st.sigma_x))
            ZtZ = (st.Z.T @ st.Z) * ibm.mask_outer(st.active)
            ZtX = (st.Z.T @ X) * st.active[:, None]
            A = ibm.a_posterior_draw(ka, ZtZ, ZtX, st.active, st.sigma_x,
                                     st.sigma_a)
            m = jnp.sum(st.Z * st.active[None, :], axis=0)
            pi = jax.random.beta(
                kp, jnp.maximum(m, 1e-6), 1.0 + N - m
            ) * st.active
            lls.append(float(train_joint_loglik(X, st.Z, A, pi, st.active,
                                                st.sigma_x)))
    return np.array(Ks), np.array(sxs), np.array(lls)


@pytest.fixture(scope="module")
def hybrid_chains(data):
    """C=4 vectorized hybrid chains; (C, T) traces of K, sigma_x, ll."""
    X = jnp.asarray(data)
    hyp = IBPHypers()
    # full-width tail (K_tail = K_max): the configuration adaptive
    # K_tail growth converges to, and the one the tightened envelopes
    # are calibrated against
    smp = build_sampler(
        SamplerSpec(P=3, K_max=K_MAX, K_tail=K_MAX, K_init=3, L=5,
                    chains="vmap", n_chains=C_CHAINS),
        hyp, data,
    )
    gs, ss = smp.init(jax.random.key(2))
    ll_fn = jax.jit(jax.vmap(train_joint_loglik,
                             in_axes=(None, 0, 0, 0, 0, 0)))
    Ks, sxs, lls = [], [], []
    for it in range(BURN + KEEP):
        gs, ss = smp.step(gs, ss)
        if it >= BURN and (it - BURN) % THIN == 0:
            Ks.append(np.asarray(jnp.sum(gs.active, axis=-1)))
            sxs.append(np.asarray(gs.sigma_x))
            Z = ss.Z.reshape(C_CHAINS, N, -1)
            lls.append(np.asarray(ll_fn(X, Z, gs.A, gs.pi, gs.active,
                                        gs.sigma_x)))
    # stack to (C, T)
    return (np.stack(Ks, axis=1), np.stack(sxs, axis=1),
            np.stack(lls, axis=1))


@pytest.mark.slow
def test_posterior_K_agrees(collapsed_chain, hybrid_chains):
    """Both samplers find the ~4 true features; E[K+] agrees within MC
    error plus the measured truncation envelope."""
    Kc, Kh = collapsed_chain[0], hybrid_chains[0]
    assert 3.5 <= Kc.mean() <= 8.0, Kc.mean()
    assert 3.5 <= Kh.mean() <= 8.0, Kh.mean()
    gap = abs(Kc.mean() - Kh.mean())
    se = np.hypot(cv.mcse(Kc), cv.mcse(Kh))
    assert gap < Z_OK * se + K_TRUNC_TOL, (Kc.mean(), Kh.mean(), gap, se)


@pytest.mark.slow
def test_posterior_sigma_x_agrees(collapsed_chain, hybrid_chains):
    """E[sigma_x] matches the true noise scale (0.5) for both samplers,
    and the samplers agree within MC error (pure z-test — sigma_x is
    likelihood-dominated, no truncation sensitivity)."""
    sc, sh = collapsed_chain[1], hybrid_chains[1]
    assert abs(sc.mean() - 0.5) < 0.08, sc.mean()
    assert abs(sh.mean() - 0.5) < 0.08, sh.mean()
    z = cv.mean_diff_z(sc, sh)
    assert abs(z) < Z_OK, (sc.mean(), sh.mean(), z)


@pytest.mark.slow
def test_posterior_joint_ll_agrees(collapsed_chain, hybrid_chains):
    """Stationary joint log-lik levels agree, draw-vs-draw, within MC
    error plus the K-coupled truncation offset."""
    lc, lh = collapsed_chain[2], hybrid_chains[2]
    gap = abs(lc.mean() - lh.mean())
    se = np.hypot(cv.mcse(lc), cv.mcse(lh))
    assert gap < Z_OK * se + LL_TRUNC_TOL, (lc.mean(), lh.mean(), gap, se)
    # backstop: the relative gap stays far inside the old 5% threshold
    assert gap / abs(lc.mean()) < 0.025, (lc.mean(), lh.mean())


@pytest.mark.slow
def test_multichain_rhat_converged(hybrid_chains):
    """Split-R-hat < 1.05 across C=4 vectorized chains on sigma_x — the
    acceptance bar for 'the chains found the same posterior'."""
    sxs = hybrid_chains[1]
    rhat = cv.split_rhat(sxs)
    assert rhat < 1.05, rhat
    # and the pooled ESS is enough for every tolerance used above
    assert cv.ess(sxs) > 40, cv.ess(sxs)


@pytest.mark.slow
def test_hybrid_is_exact_not_approximate(hybrid_chains):
    """The hybrid chains mix over K (features born AND die) — evidence the
    tail proposal is live, unlike approximate parallel IBP samplers that
    freeze the feature set between syncs."""
    Ks = hybrid_chains[0]
    assert Ks.std() > 0 or len(np.unique(Ks)) > 1 or Ks.mean() >= 4


@pytest.mark.slow
def test_k_gap_shrinks_as_tail_widens(data, collapsed_chain):
    """The truncation mechanism behind the K+ envelope: the
    hybrid-vs-collapsed stationary E[K+] gap is MONOTONE in K_tail
    (K_tail caps in-flight births, biasing K+ down), and at the
    full-width tail — what adaptive k_tail_grow converges to — the gap
    is inside the tightened envelope. Measured at these settings:
    E[K+] ~= 5.78 / 5.83 / 6.13 at K_tail = 1 / 2 / 12 against a
    collapsed ~6.2."""
    X = jnp.asarray(data)
    hyp = IBPHypers()
    burn, keep = 150, 300
    means, ses = [], []
    for K_tail in (1, 2, K_MAX):
        smp = build_sampler(
            SamplerSpec(P=3, K_max=K_MAX, K_tail=K_tail, K_init=3, L=5,
                        chains="vmap", n_chains=C_CHAINS),
            hyp, data,
        )
        gs, ss = smp.init(jax.random.key(2))
        Ks = []
        for it in range(burn + keep):
            gs, ss = smp.step(gs, ss)
            if it >= burn and (it - burn) % THIN == 0:
                Ks.append(np.asarray(jnp.sum(gs.active, axis=-1)))
        Kh = np.stack(Ks, axis=1)
        means.append(Kh.mean())
        ses.append(cv.mcse(Kh))
    Kc = collapsed_chain[0].mean()
    gaps = [abs(Kc - m) for m in means]
    # E[K+] recovers monotonically toward the collapsed level as the
    # tail widens (2-mcse slack per step for cross-platform float drift)
    for lo, hi in zip(range(len(means) - 1), range(1, len(means))):
        slack = 2.0 * float(np.hypot(ses[lo], ses[hi]))
        assert means[hi] > means[lo] - slack, (means, ses)
    # and the widest tail clearly beats the narrowest (measured ~0.42
    # vs ~0.07) and sits inside the tightened envelope
    assert gaps[-1] + 0.15 < gaps[0], (gaps, means, Kc)
    assert gaps[-1] < K_TRUNC_TOL, (gaps[-1], K_TRUNC_TOL)


# ---------------------------------------------------------------------------
# Geweke-style "getting it right" joint-distribution check
# ---------------------------------------------------------------------------

GW_N, GW_D, GW_KMAX = 16, 6, 8
GW_ITERS, GW_BURN, GW_THIN = 5000, 1200, 3
GW_SX, GW_SA, GW_ALPHA = 0.8, 1.0, 2.0

# The Geweke chains already run the full-width tail (K_tail = GW_KMAX),
# so their K+ gap (~1.3 measured) is purely the STRUCTURAL truncation —
# births on p' only and J_MAX per row — which prior-land at N=16
# exaggerates (every row regenerates, half the rows can never birth).
# It therefore keeps its own envelope instead of the posterior-land
# K_TRUNC_TOL that full-width K_tail tightened to 0.8.
GW_K_TRUNC_TOL = 1.5


def _gw_hyp():
    # sigmas fixed: InvGamma(1,1) has no prior mean, so prior-land
    # sigma chains have unusable moments; alpha fixed pins E[K+]
    return IBPHypers(resample_sigmas=False, resample_alpha=False)


@pytest.fixture(scope="module")
def geweke_hybrid():
    """Successive-conditional simulator for the hybrid kernel:
    theta' ~ K_hybrid(theta; X), then X ~ p(X | theta')."""
    P = 2
    key = jax.random.key(0)
    Xs = jax.random.normal(jax.random.key(99), (P, GW_N // P, GW_D))
    gs, ss = init_hybrid(jax.random.key(1), Xs, GW_KMAX, K_tail=GW_KMAX,
                         alpha=GW_ALPHA, sigma_x=GW_SX, sigma_a=GW_SA,
                         K_init=4, init_from_data=False)
    hyp = _gw_hyp()
    # X is REGENERATED between transitions, so the step comes from the
    # low-level constructor (a Sampler closes over fixed data)
    step = build_hybrid_fns(
        SamplerSpec(P=P, K_max=GW_KMAX, K_tail=GW_KMAX, L=3),
        hyp, N_global=GW_N,
    ).step
    Ks, ms = [], []
    for it in range(GW_ITERS):
        gs, ss = step(Xs, gs, ss)
        key, ke = jax.random.split(key)
        mean = (ss.Z * gs.active[None, None, :]) @ gs.A
        Xs = mean + gs.sigma_x * jax.random.normal(ke, mean.shape)
        if it >= GW_BURN and it % GW_THIN == 0:
            Ks.append(float(jnp.sum(gs.active)))
            ms.append(float(jnp.sum(ss.Z * gs.active[None, None, :])))
    return np.array(Ks), np.array(ms)


@pytest.fixture(scope="module")
def geweke_collapsed():
    """Successive-conditional simulator for the collapsed kernel (with
    the same A-draw + X-regeneration moves, all exact conditionals)."""
    key = jax.random.key(10)
    st = init_state(jax.random.key(2), GW_N, GW_D, GW_KMAX, alpha=GW_ALPHA,
                    sigma_x=GW_SX, sigma_a=GW_SA, K_init=4)
    X = jax.random.normal(jax.random.key(98), (GW_N, GW_D))
    hyp = _gw_hyp()
    Ks, ms = [], []
    for it in range(GW_ITERS):
        st = collapsed_sweep(st, X, hyp)
        key, ka, ke = jax.random.split(key, 3)
        Zm = st.Z * st.active[None, :]
        ZtZ = (Zm.T @ Zm) * ibm.mask_outer(st.active)
        ZtX = (Zm.T @ X) * st.active[:, None]
        A = ibm.a_posterior_draw(ka, ZtZ, ZtX, st.active, st.sigma_x,
                                 st.sigma_a)
        X = Zm @ A + st.sigma_x * jax.random.normal(ke, X.shape)
        if it >= GW_BURN and it % GW_THIN == 0:
            Ks.append(float(st.k_plus))
            ms.append(float(jnp.sum(Zm)))
    return np.array(Ks), np.array(ms)


@pytest.mark.slow
def test_geweke_joint_distribution(geweke_hybrid, geweke_collapsed):
    """Getting it right (Geweke 2004): each kernel's successive-conditional
    chain is stationary, and the two chains agree on the prior-land
    moments of the joint — assignment mass by pure z-test, K+ within the
    measured truncation envelope (the kernels truncate the IBP tail
    differently; see module docstring)."""
    hK, hm = geweke_hybrid
    cK, cm = geweke_collapsed
    # stationarity of each simulator (no within-chain drift)
    assert abs(cv.geweke_z(hK)) < Z_OK, cv.geweke_z(hK)
    assert abs(cv.geweke_z(cK)) < Z_OK, cv.geweke_z(cK)
    # prior-land E[K+] is near alpha * H_N for both kernels
    prior_K = GW_ALPHA * float(np.sum(1.0 / np.arange(1, GW_N + 1)))
    for name, Ks in (("hybrid", hK), ("collapsed", cK)):
        assert abs(Ks.mean() - prior_K) < 3.0, (name, Ks.mean(), prior_K)
    # cross-kernel agreement
    zm = cv.mean_diff_z(cm, hm)
    assert abs(zm) < Z_OK + 1.0, (cm.mean(), hm.mean(), zm)
    gapK = abs(cK.mean() - hK.mean())
    seK = np.hypot(cv.mcse(cK), cv.mcse(hK))
    assert gapK < Z_OK * seK + GW_K_TRUNC_TOL, (cK.mean(), hK.mean(), gapK)
