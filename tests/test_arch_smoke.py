"""Per-architecture smoke tests (deliverable f): reduced same-family configs,
one forward + train step + decode step on CPU; output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import (
    init_caches,
    init_model,
    make_decode_step,
    make_train_step,
    model_apply,
)
from repro.optim import AdamW


def _batch(cfg, B, S):
    batch = {"tokens": jnp.zeros((B, S), jnp.int32) + 3}
    if cfg.family == "encdec":
        batch["frames"] = jnp.ones((B, cfg.enc_seq, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        batch["patches"] = jnp.ones((B, cfg.stub_tokens, cfg.d_model),
                                    jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = get_config(arch, smoke=True)
    params, _ = init_model(jax.random.key(0), cfg)
    B, S = 2, 32
    batch = _batch(cfg, B, S)

    logits, aux, _ = model_apply(params, batch, cfg, mode="train")
    assert logits.shape[:2] == (B, S)
    assert bool(jnp.all(jnp.isfinite(logits)))

    opt = AdamW(lr=1e-3)
    state = opt.init(params)
    step = jax.jit(make_train_step(cfg, opt))
    p2, s2, metrics = step(params, state, batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually moved
    moved = any(
        float(jnp.max(jnp.abs(a - b))) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2))
    )
    assert moved


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step(arch):
    cfg = get_config(arch, smoke=True)
    params, _ = init_model(jax.random.key(0), cfg)
    B, S = 2, 16
    caches = init_caches(cfg, B, S)
    batch = {"tokens": jnp.zeros((B, 1), jnp.int32) + 3}
    if cfg.family == "encdec":
        batch["enc_out"] = jnp.ones((B, cfg.enc_seq, cfg.d_model), jnp.float32)
    step = jax.jit(make_decode_step(cfg))
    tok, caches2 = step(params, batch, caches)
    assert tok.shape == (B,)
    assert bool(jnp.all((tok >= 0)))
    # cache lengths advanced
    lens = [x for x in jax.tree.leaves(caches2) if x.dtype == jnp.int32]
    assert all(int(l.reshape(-1)[0]) == 1 for l in lens)


@pytest.mark.parametrize("arch", ["smollm-135m", "falcon-mamba-7b",
                                  "recurrentgemma-2b"])
def test_decode_matches_prefill_logits(arch):
    """Teacher-forced decode must reproduce the train-mode forward's
    next-token argmax (cache correctness)."""
    cfg = get_config(arch, smoke=True)
    params, _ = init_model(jax.random.key(0), cfg)
    B, S = 2, 12
    toks = jax.random.randint(jax.random.key(5), (B, S), 0, cfg.vocab,
                              jnp.int32)
    logits, _, _ = model_apply(params, {"tokens": toks}, cfg, mode="train")

    caches = init_caches(cfg, B, S + 1)
    step = jax.jit(make_decode_step(cfg))
    decode_logits = []
    for i in range(S):
        # reuse internals: run decode and capture via argmax comparison only
        tok, caches = step(params, {"tokens": toks[:, i:i + 1]}, caches)
        decode_logits.append(tok)
    # compare final-position argmax
    want = jnp.argmax(logits[:, -1], axis=-1)
    got = decode_logits[-1]
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_full_configs_match_assignment():
    """The exact numbers from the assignment table."""
    c = get_config("granite-3-8b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (40, 4096, 32, 8, 12800, 49155)
    c = get_config("deepseek-v2-236b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_experts, c.top_k,
            c.kv_lora_rank, c.vocab) == (60, 5120, 128, 160, 6, 512, 102400)
    assert c.n_shared_experts == 2
    c = get_config("whisper-large-v3")
    assert (c.n_layers, c.n_enc_layers, c.d_model, c.n_heads, c.d_ff,
            c.vocab) == (32, 32, 1280, 20, 5120, 51866)
    c = get_config("falcon-mamba-7b")
    assert (c.n_layers, c.d_model, c.ssm_state, c.vocab) == (64, 4096, 16,
                                                             65024)
    c = get_config("recurrentgemma-2b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab,
            c.local_window) == (26, 2560, 10, 1, 7680, 256000, 2048)
    c = get_config("phi3.5-moe-42b-a6.6b")
    assert (c.n_experts, c.top_k, c.d_ff_expert) == (16, 2, 6400)
    c = get_config("minicpm3-4b")
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff, c.vocab) == (
        62, 2560, 40, 6400, 73448)
    c = get_config("smollm-135m")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (30, 576, 9, 3, 1536, 49152)
    c = get_config("codeqwen1.5-7b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (32, 4096, 32, 32, 13440, 92416)
    c = get_config("internvl2-76b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (80, 8192, 64, 8, 28672, 128256)


def test_param_count_ballpark():
    """Sanity: param_count() lands within 2x of the nameplate size."""
    import math
    for arch, lo, hi in [
        ("granite-3-8b", 4e9, 12e9),
        ("codeqwen1.5-7b", 4e9, 11e9),
        ("smollm-135m", 0.9e8, 2.2e8),
        ("falcon-mamba-7b", 4e9, 11e9),
        ("deepseek-v2-236b", 150e9, 320e9),
        ("internvl2-76b", 50e9, 110e9),
    ]:
        n = get_config(arch).param_count()
        assert lo <= n <= hi, (arch, n)
