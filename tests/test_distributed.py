"""Multi-device tests, run in subprocesses so the main pytest process keeps a
single CPU device (the dry-run contract: only dryrun.py forces many devices)."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_with_devices(code: str, n_devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_shardmap_hybrid_runs_and_converges():
    out = run_with_devices("""
        import jax
        from repro.data import cambridge_data
        from repro.core.ibp import IBPHypers, SamplerSpec, build_sampler
        X, _, _ = cambridge_data(N=96, seed=1)
        spec = SamplerSpec(P=8, K_max=16, K_tail=6, K_init=4, L=5,
                           data='shardmap')
        s = build_sampler(spec, IBPHypers(), X)
        gs, st = s.init(jax.random.key(1))
        for _ in range(40):
            gs, st = s.step(gs, st)
        K = int(gs.active.sum()); sx = float(gs.sigma_x)
        assert 3 <= K <= 9, K
        assert 0.3 <= sx <= 0.75, sx
        print('OK', K, sx)
    """)
    assert "OK" in out


def test_shardmap_matches_vmap_semantics():
    """The shard_map layout and the vmap layout produce identical states
    under identical keys (they implement the same algorithm), starting
    from the same canonical state."""
    out = run_with_devices("""
        import numpy as np, jax
        from repro.data import cambridge_data
        from repro.core.ibp import IBPHypers, SamplerSpec, build_sampler
        X, _, _ = cambridge_data(N=32, seed=4)
        hyp = IBPHypers()
        spec = SamplerSpec(P=4, K_max=12, K_tail=4, K_init=3, L=2)
        sv = build_sampler(spec, hyp, X)
        sm = build_sampler(spec.replace(data='shardmap'), hyp, X)
        gs_v, st_v = sv.init(jax.random.key(2))
        gs_s = gs_v
        st_s = sm.from_canonical(sv.to_canonical(st_v))
        for _ in range(5):
            gs_v, st_v = sv.step(gs_v, st_v)
            gs_s, st_s = sm.step(gs_s, st_s)
        np.testing.assert_array_equal(
            np.asarray(sv.to_canonical(st_v).Z),
            np.asarray(sm.to_canonical(st_s).Z))
        # float scalars agree up to reduction-ordering ULPs (psum vs axis-sum)
        np.testing.assert_allclose(float(gs_v.sigma_x), float(gs_s.sigma_x),
                                   rtol=1e-5)
        np.testing.assert_allclose(float(gs_v.sigma_a), float(gs_s.sigma_a),
                                   rtol=1e-5)
        np.testing.assert_allclose(np.asarray(gs_v.A), np.asarray(gs_s.A),
                                   atol=1e-5)
        assert int(gs_v.p_prime) == int(gs_s.p_prime)
        print('OK identical')
    """)
    assert "OK identical" in out


def test_fused_sync_matches_staged():
    """The fused single-all-reduce master sync (SSE via the trace identity,
    tail mask folded into the stats payload) computes the same iteration as
    the staged 3-all-reduce schedule, up to reduction-order ULPs."""
    out = run_with_devices("""
        import numpy as np, jax
        from repro.data import cambridge_data
        from repro.core.ibp import IBPHypers, SamplerSpec, build_sampler
        X, _, _ = cambridge_data(N=64, seed=9)
        hyp = IBPHypers()
        outs = {}
        for sync in ('staged', 'fused'):
            spec = SamplerSpec(P=4, K_max=12, K_tail=4, K_init=3, L=2,
                               data='shardmap', sync=sync)
            s = build_sampler(spec, hyp, X)
            gs, st = s.init(jax.random.key(3))
            for _ in range(3):
                gs, st = s.step(gs, st)
                jax.block_until_ready(st[0])
            outs[sync] = (np.asarray(st[0]), np.asarray(gs.A),
                          float(gs.sigma_x), np.asarray(gs.active))
        np.testing.assert_array_equal(outs['staged'][0], outs['fused'][0])
        np.testing.assert_allclose(outs['staged'][1], outs['fused'][1],
                                   atol=1e-4)
        np.testing.assert_allclose(outs['staged'][2], outs['fused'][2],
                                   rtol=1e-4)
        np.testing.assert_array_equal(outs['staged'][3], outs['fused'][3])
        print('OK fused == staged')
    """, n_devices=4)
    assert "OK fused == staged" in out


def test_moe_a2a_matches_gather_dispatch():
    """The shard_map all-to-all MoE dispatch computes the same function as
    the global-capacity gather baseline when nothing drops (capacity_factor
    large): same forward output, same aux loss, on a (data=2, model=2) mesh."""
    out = run_with_devices("""
        import dataclasses, numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.compat import AxisType, make_mesh, set_mesh
        from repro.configs import get_config
        from repro.models import init_model, ActSpecs
        from repro.models.moe import moe_apply
        from repro.parallel.mesh import act_specs

        cfg = get_config('phi3.5-moe-42b-a6.6b', smoke=True)
        cfg = dataclasses.replace(cfg, n_experts=8, top_k=2, d_model=32,
                                  d_ff_expert=16, capacity_factor=8.0,
                                  n_shared_experts=1)
        from repro.models.moe import moe_init
        p, _ = moe_init(jax.random.key(0), cfg)
        x = jax.random.normal(jax.random.key(1), (4, 8, 32), jnp.float32)

        # reference: single-device gather dispatch
        cfg_g = dataclasses.replace(cfg, moe_impl='gather')
        y_ref, aux_ref = moe_apply(p, x, cfg_g)

        mesh = make_mesh((2, 2), ('data', 'model'),
                         axis_types=(AxisType.Auto,) * 2)
        with set_mesh(mesh):
            specs = act_specs(mesh, seq_len=8, batch=4, mode='train')
            cfg_a = dataclasses.replace(cfg, moe_impl='a2a')
            y_a2a, aux_a2a = jax.jit(
                lambda p, x: moe_apply(p, x, cfg_a, specs=specs)
            )(p, x)
        np.testing.assert_allclose(np.asarray(y_a2a), np.asarray(y_ref),
                                   rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(float(aux_a2a), float(aux_ref), rtol=1e-5)

        # and it differentiates (grads flow through both all_to_alls)
        def loss(p, x):
            y, aux = moe_apply(p, x, cfg_a, specs=specs)
            return jnp.sum(y * y) + 0.01 * aux
        with set_mesh(mesh):
            g = jax.jit(jax.grad(loss))(p, x)
        assert all(np.all(np.isfinite(v)) for v in jax.tree.leaves(
            jax.tree.map(np.asarray, g)))
        gn = float(jnp.linalg.norm(g['wi']))
        assert gn > 0, gn
        print('OK a2a == gather, grad norm', gn)
    """, n_devices=4)
    assert "OK a2a == gather" in out


def test_lm_train_step_shards_on_8_devices():
    """A reduced LM train step pjit-shards over a (4, 2) data x model mesh."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.compat import AxisType, make_mesh, set_mesh
        from repro.configs import get_config
        from repro.models import init_model, make_train_step
        from repro.models.transformer import ActSpecs
        from repro.optim import AdamW
        from repro.parallel.mesh import (act_specs, batch_specs, named,
                                         resolve_param_specs)
        import dataclasses
        cfg = get_config('granite-3-8b', smoke=True)
        cfg = dataclasses.replace(cfg, d_model=64, n_heads=4, n_kv_heads=2,
                                  d_ff=128)
        mesh = make_mesh((4, 2), ('data', 'model'),
                         axis_types=(AxisType.Auto,) * 2)
        with set_mesh(mesh):
            holder = {}
            def build(k):
                p, s = init_model(k, cfg)
                holder['s'] = s
                return p
            params = build(jax.random.key(0))
            pspec = resolve_param_specs(holder['s'], params, mesh, mode='train')
            p_sh = named(mesh, pspec)
            params = jax.device_put(params, p_sh)
            opt = AdamW(lr=1e-3)
            ost = opt.init(params)
            batch = {'tokens': jnp.zeros((8, 32), jnp.int32) + 5}
            specs = act_specs(mesh, seq_len=32, batch=8, mode='train')
            step = jax.jit(make_train_step(cfg, opt, specs))
            p2, o2, m = step(params, ost, batch)
            assert np.isfinite(float(m['loss']))
            # a TP-sharded weight is actually distributed
            w = p2['layers']['attn']['wq']
            assert len(w.sharding.device_set) > 1
            print('OK sharded loss', float(m['loss']))
    """)
    assert "OK sharded" in out


def test_driver_shardmap_backend_selectable():
    """MCMCDriver with driver='shardmap' runs the production collective path
    end to end (checkpointing included) on 8 forced host devices, and its
    checkpoints remain interchangeable with the vmap backend."""
    out = run_with_devices("""
        import dataclasses, tempfile, numpy as np
        from repro.core.ibp import IBPHypers
        from repro.data import cambridge_data
        from repro.runtime import DriverConfig, MCMCDriver
        X, _, _ = cambridge_data(N=96, seed=5)
        d = tempfile.mkdtemp()
        cfg = DriverConfig(P=8, K_max=16, K_tail=6, L=3, n_iters=20,
                           ckpt_every=10, eval_every=10, driver='shardmap',
                           stale_sync=1, ckpt_dir=d)
        drv = MCMCDriver(X, cfg, IBPHypers())
        gs, ss = drv.run()
        K = int(gs.active.sum()); sx = float(gs.sigma_x)
        assert 2 <= K <= 10, K
        assert 0.3 <= sx <= 0.8, sx
        assert ss.Z.shape[0] == 8
        assert 'sigma_x_rhat' in drv.history[-1]
        # same checkpoint resumes on the vmap backend (elastic P too)
        cfg_v = dataclasses.replace(cfg, driver='vmap', P=4, n_iters=25)
        gs2, ss2 = MCMCDriver(X, cfg_v, IBPHypers()).run()
        assert int(gs2.it) == 25 and ss2.Z.shape[0] == 4
        print('OK shardmap driver', K, sx)
    """)
    assert "OK shardmap driver" in out
