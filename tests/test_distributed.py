"""Multi-device tests, run in subprocesses so the main pytest process keeps a
single CPU device (the dry-run contract: only dryrun.py forces many devices)."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_with_devices(code: str, n_devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_shardmap_hybrid_runs_and_converges():
    out = run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.compat import AxisType, make_mesh, set_mesh
        from repro.data import cambridge_data, shard_rows
        from repro.core.ibp import IBPHypers, init_hybrid, make_hybrid_iteration_shardmap
        X, _, _ = cambridge_data(N=96, seed=1)
        Pn = 8
        mesh = make_mesh((Pn,), ('data',), axis_types=(AxisType.Auto,))
        Xs = jnp.asarray(shard_rows(X, Pn))
        gs, ss = init_hybrid(jax.random.key(1), Xs, K_max=16, K_tail=6, K_init=4)
        step = make_hybrid_iteration_shardmap(mesh, ('data',), IBPHypers(),
                                              L=5, N_global=96)
        with set_mesh(mesh):
            sh = NamedSharding(mesh, P('data'))
            Xf = jax.device_put(Xs.reshape(-1, 36), sh)
            Zf = jax.device_put(ss.Z.reshape(-1, 16), sh)
            Zt = jax.device_put(ss.Z_tail.reshape(-1, 6), sh)
            ta = jax.device_put(ss.tail_active, sh)
            for _ in range(40):
                gs, Zf, Zt, ta = step(Xf, gs, Zf, Zt, ta)
        K = int(gs.active.sum()); sx = float(gs.sigma_x)
        assert 3 <= K <= 9, K
        assert 0.3 <= sx <= 0.75, sx
        print('OK', K, sx)
    """)
    assert "OK" in out


def test_shardmap_matches_vmap_semantics():
    """The shard_map driver and the vmap driver produce identical states under
    identical keys (they implement the same algorithm)."""
    out = run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.compat import AxisType, make_mesh, set_mesh
        from repro.data import cambridge_data, shard_rows
        from repro.core.ibp import (IBPHypers, init_hybrid,
                                    hybrid_iteration_vmap,
                                    make_hybrid_iteration_shardmap)
        X, _, _ = cambridge_data(N=32, seed=4)
        Pn = 4
        hyp = IBPHypers()
        Xs = jnp.asarray(shard_rows(X, Pn))
        gs_v, ss_v = init_hybrid(jax.random.key(2), Xs, K_max=12, K_tail=4,
                                 K_init=3)
        gs_s, ss_s = gs_v, ss_v
        # vmap path
        for _ in range(5):
            gs_v, ss_v = hybrid_iteration_vmap(Xs, gs_v, ss_v, hyp, L=2,
                                               N_global=32)
        # shard_map path
        mesh = make_mesh((Pn,), ('data',), axis_types=(AxisType.Auto,))
        step = make_hybrid_iteration_shardmap(mesh, ('data',), hyp, L=2,
                                              N_global=32)
        with set_mesh(mesh):
            sh = NamedSharding(mesh, P('data'))
            Xf = jax.device_put(Xs.reshape(-1, 36), sh)
            Zf = jax.device_put(ss_s.Z.reshape(-1, 12), sh)
            Zt = jax.device_put(ss_s.Z_tail.reshape(-1, 4), sh)
            ta = jax.device_put(ss_s.tail_active, sh)
            for _ in range(5):
                gs_s, Zf, Zt, ta = step(Xf, gs_s, Zf, Zt, ta)
        np.testing.assert_array_equal(
            np.asarray(ss_v.Z.reshape(-1, 12)), np.asarray(Zf))
        # float scalars agree up to reduction-ordering ULPs (psum vs axis-sum)
        np.testing.assert_allclose(float(gs_v.sigma_x), float(gs_s.sigma_x),
                                   rtol=1e-5)
        np.testing.assert_allclose(float(gs_v.sigma_a), float(gs_s.sigma_a),
                                   rtol=1e-5)
        np.testing.assert_allclose(np.asarray(gs_v.A), np.asarray(gs_s.A),
                                   atol=1e-5)
        assert int(gs_v.p_prime) == int(gs_s.p_prime)
        print('OK identical')
    """)
    assert "OK identical" in out


def test_fused_sync_matches_staged():
    """The fused single-all-reduce master sync (SSE via the trace identity,
    tail mask folded into the stats payload) computes the same iteration as
    the staged 3-all-reduce schedule, up to reduction-order ULPs."""
    out = run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.compat import AxisType, make_mesh, set_mesh
        from repro.data import cambridge_data, shard_rows
        from repro.core.ibp import (IBPHypers, init_hybrid,
                                    make_hybrid_iteration_shardmap)
        X, _, _ = cambridge_data(N=64, seed=9)
        Pn, Km, Kt = 4, 12, 4
        hyp = IBPHypers()
        Xs = jnp.asarray(shard_rows(X, Pn))
        mesh = make_mesh((Pn,), ('data',), axis_types=(AxisType.Auto,))
        outs = {}
        for sync in ('staged', 'fused'):
            gs, ss = init_hybrid(jax.random.key(3), Xs, Km, K_tail=Kt,
                                 K_init=3)
            step = make_hybrid_iteration_shardmap(mesh, ('data',), hyp, L=2,
                                                  N_global=64, sync=sync)
            with set_mesh(mesh):
                sh = NamedSharding(mesh, P('data'))
                Xf = jax.device_put(Xs.reshape(-1, 36), sh)
                Zf = jax.device_put(ss.Z.reshape(-1, Km), sh)
                Zt = jax.device_put(ss.Z_tail.reshape(-1, Kt), sh)
                ta = jax.device_put(ss.tail_active, sh)
                for _ in range(3):
                    gs, Zf, Zt, ta = step(Xf, gs, Zf, Zt, ta)
                    jax.block_until_ready(Zf)
            outs[sync] = (np.asarray(Zf), np.asarray(gs.A),
                          float(gs.sigma_x), np.asarray(gs.active))
        np.testing.assert_array_equal(outs['staged'][0], outs['fused'][0])
        np.testing.assert_allclose(outs['staged'][1], outs['fused'][1],
                                   atol=1e-4)
        np.testing.assert_allclose(outs['staged'][2], outs['fused'][2],
                                   rtol=1e-4)
        np.testing.assert_array_equal(outs['staged'][3], outs['fused'][3])
        print('OK fused == staged')
    """, n_devices=4)
    assert "OK fused == staged" in out


def test_moe_a2a_matches_gather_dispatch():
    """The shard_map all-to-all MoE dispatch computes the same function as
    the global-capacity gather baseline when nothing drops (capacity_factor
    large): same forward output, same aux loss, on a (data=2, model=2) mesh."""
    out = run_with_devices("""
        import dataclasses, numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.compat import AxisType, make_mesh, set_mesh
        from repro.configs import get_config
        from repro.models import init_model, ActSpecs
        from repro.models.moe import moe_apply
        from repro.parallel.mesh import act_specs

        cfg = get_config('phi3.5-moe-42b-a6.6b', smoke=True)
        cfg = dataclasses.replace(cfg, n_experts=8, top_k=2, d_model=32,
                                  d_ff_expert=16, capacity_factor=8.0,
                                  n_shared_experts=1)
        from repro.models.moe import moe_init
        p, _ = moe_init(jax.random.key(0), cfg)
        x = jax.random.normal(jax.random.key(1), (4, 8, 32), jnp.float32)

        # reference: single-device gather dispatch
        cfg_g = dataclasses.replace(cfg, moe_impl='gather')
        y_ref, aux_ref = moe_apply(p, x, cfg_g)

        mesh = make_mesh((2, 2), ('data', 'model'),
                         axis_types=(AxisType.Auto,) * 2)
        with set_mesh(mesh):
            specs = act_specs(mesh, seq_len=8, batch=4, mode='train')
            cfg_a = dataclasses.replace(cfg, moe_impl='a2a')
            y_a2a, aux_a2a = jax.jit(
                lambda p, x: moe_apply(p, x, cfg_a, specs=specs)
            )(p, x)
        np.testing.assert_allclose(np.asarray(y_a2a), np.asarray(y_ref),
                                   rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(float(aux_a2a), float(aux_ref), rtol=1e-5)

        # and it differentiates (grads flow through both all_to_alls)
        def loss(p, x):
            y, aux = moe_apply(p, x, cfg_a, specs=specs)
            return jnp.sum(y * y) + 0.01 * aux
        with set_mesh(mesh):
            g = jax.jit(jax.grad(loss))(p, x)
        assert all(np.all(np.isfinite(v)) for v in jax.tree.leaves(
            jax.tree.map(np.asarray, g)))
        gn = float(jnp.linalg.norm(g['wi']))
        assert gn > 0, gn
        print('OK a2a == gather, grad norm', gn)
    """, n_devices=4)
    assert "OK a2a == gather" in out


def test_lm_train_step_shards_on_8_devices():
    """A reduced LM train step pjit-shards over a (4, 2) data x model mesh."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.compat import AxisType, make_mesh, set_mesh
        from repro.configs import get_config
        from repro.models import init_model, make_train_step
        from repro.models.transformer import ActSpecs
        from repro.optim import AdamW
        from repro.parallel.mesh import (act_specs, batch_specs, named,
                                         resolve_param_specs)
        import dataclasses
        cfg = get_config('granite-3-8b', smoke=True)
        cfg = dataclasses.replace(cfg, d_model=64, n_heads=4, n_kv_heads=2,
                                  d_ff=128)
        mesh = make_mesh((4, 2), ('data', 'model'),
                         axis_types=(AxisType.Auto,) * 2)
        with set_mesh(mesh):
            holder = {}
            def build(k):
                p, s = init_model(k, cfg)
                holder['s'] = s
                return p
            params = build(jax.random.key(0))
            pspec = resolve_param_specs(holder['s'], params, mesh, mode='train')
            p_sh = named(mesh, pspec)
            params = jax.device_put(params, p_sh)
            opt = AdamW(lr=1e-3)
            ost = opt.init(params)
            batch = {'tokens': jnp.zeros((8, 32), jnp.int32) + 5}
            specs = act_specs(mesh, seq_len=32, batch=8, mode='train')
            step = jax.jit(make_train_step(cfg, opt, specs))
            p2, o2, m = step(params, ost, batch)
            assert np.isfinite(float(m['loss']))
            # a TP-sharded weight is actually distributed
            w = p2['layers']['attn']['wq']
            assert len(w.sharding.device_set) > 1
            print('OK sharded loss', float(m['loss']))
    """)
    assert "OK sharded" in out


def test_driver_shardmap_backend_selectable():
    """MCMCDriver with driver='shardmap' runs the production collective path
    end to end (checkpointing included) on 8 forced host devices, and its
    checkpoints remain interchangeable with the vmap backend."""
    out = run_with_devices("""
        import dataclasses, tempfile, numpy as np
        from repro.core.ibp import IBPHypers
        from repro.data import cambridge_data
        from repro.runtime import DriverConfig, MCMCDriver
        X, _, _ = cambridge_data(N=96, seed=5)
        d = tempfile.mkdtemp()
        cfg = DriverConfig(P=8, K_max=16, K_tail=6, L=3, n_iters=20,
                           ckpt_every=10, eval_every=10, driver='shardmap',
                           stale_sync=1, ckpt_dir=d)
        drv = MCMCDriver(X, cfg, IBPHypers())
        gs, ss = drv.run()
        K = int(gs.active.sum()); sx = float(gs.sigma_x)
        assert 2 <= K <= 10, K
        assert 0.3 <= sx <= 0.8, sx
        assert ss.Z.shape[0] == 8
        assert 'sigma_x_rhat' in drv.history[-1]
        # same checkpoint resumes on the vmap backend (elastic P too)
        cfg_v = dataclasses.replace(cfg, driver='vmap', P=4, n_iters=25)
        gs2, ss2 = MCMCDriver(X, cfg_v, IBPHypers()).run()
        assert int(gs2.it) == 25 and ss2.Z.shape[0] == 4
        print('OK shardmap driver', K, sx)
    """)
    assert "OK shardmap driver" in out
