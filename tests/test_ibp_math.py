"""Unit tests for the IBP math layer against float64 numpy oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp_compat import given_or_params

from repro.core.ibp import math as ibm

jax.config.update("jax_enable_x64", False)


def np_collapsed_loglik(X, Z, sx, sa):
    """Direct float64 evaluation of G&G Eq. 26."""
    N, D = X.shape
    K = Z.shape[1]
    W = Z.T @ Z + (sx / sa) ** 2 * np.eye(K)
    M = np.linalg.inv(W)
    s, logdet = np.linalg.slogdet(W)
    assert s > 0
    mid = np.eye(N) - Z @ M @ Z.T
    tr = np.trace(X.T @ mid @ X)
    return (
        -0.5 * N * D * np.log(2 * np.pi)
        - (N - K) * D * np.log(sx)
        - K * D * np.log(sa)
        - 0.5 * D * logdet
        - tr / (2 * sx**2)
    )


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("N,D,K,K_max", [(20, 8, 3, 6), (50, 16, 5, 12)])
def test_collapsed_loglik_matches_numpy(seed, N, D, K, K_max):
    rng = np.random.default_rng(seed)
    Z = (rng.random((N, K)) < 0.4).astype(np.float64)
    Z[:, 0] = 1  # ensure non-degenerate
    A = rng.standard_normal((K, D))
    X = Z @ A + 0.3 * rng.standard_normal((N, D))
    sx, sa = 0.5, 1.2

    want = np_collapsed_loglik(X, Z, sx, sa)

    Zp = np.zeros((N, K_max), np.float32)
    Zp[:, :K] = Z
    active = np.zeros(K_max, np.float32)
    active[:K] = 1
    got = ibm.collapsed_loglik(
        jnp.float32((X * X).sum()),
        jnp.asarray(Zp.T @ X, jnp.float32),
        jnp.asarray(Zp.T @ Zp, jnp.float32),
        jnp.asarray(active),
        jnp.float32(N),
        D,
        jnp.float32(sx),
        jnp.float32(sa),
    )
    assert np.isclose(float(got), want, rtol=1e-4), (float(got), want)


def test_sherman_morrison_updates():
    rng = np.random.default_rng(0)
    K = 8
    W = np.eye(K) * 2.0
    Z = (rng.random((30, K)) < 0.5).astype(np.float64)
    W = Z.T @ Z + 0.7 * np.eye(K)
    M = np.linalg.inv(W)
    z = (rng.random(K) < 0.5).astype(np.float64)

    M1, ld1 = ibm.sm_update(jnp.asarray(M, jnp.float32), jnp.asarray(z, jnp.float32))
    want = np.linalg.inv(W + np.outer(z, z))
    np.testing.assert_allclose(np.asarray(M1), want, rtol=1e-4, atol=1e-5)
    s, want_ld = np.linalg.slogdet(W + np.outer(z, z))
    _, base_ld = np.linalg.slogdet(W)
    assert np.isclose(float(ld1), want_ld - base_ld, rtol=1e-4)

    M2, ld2 = ibm.sm_downdate(jnp.asarray(want, jnp.float32), jnp.asarray(z, jnp.float32))
    np.testing.assert_allclose(np.asarray(M2), M, rtol=1e-3, atol=1e-4)


def _padded_chol_case(n, k_max, k_act, seed):
    """Random SPD W padded to k_max with an active mask + a masked binary x."""
    rng = np.random.default_rng(seed)
    act = np.zeros(k_max, np.float32)
    act[np.sort(rng.choice(k_max, size=k_act, replace=False))] = 1.0
    Zcols = (rng.random((n, k_max)) < 0.5).astype(np.float64) * act
    W = Zcols.T @ Zcols + 0.7 * np.diag(act) + np.diag(1.0 - act)
    x = (rng.random(k_max) < 0.5).astype(np.float64) * act
    return W, x, act


@given_or_params(max_examples=25, n=(8, 60), k_max=(2, 24), seed=(0, 10_000))
def test_chol_rank1_update_matches_fresh_factorization(n, k_max, seed):
    rng = np.random.default_rng(seed)
    k_act = int(rng.integers(1, k_max + 1))
    W, x, act = _padded_chol_case(n, k_max, k_act, seed)
    L = np.linalg.cholesky(W)
    got = ibm.chol_rank1_update(jnp.asarray(L, jnp.float32),
                                jnp.asarray(x, jnp.float32))
    want = np.linalg.cholesky(W + np.outer(x, x))
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-4, atol=2e-4)
    # padding transparency: inactive rows/cols stay exactly identity
    inact = act < 0.5
    got = np.asarray(got)
    assert np.all(got[inact][:, ~inact] == 0)
    assert np.all(got[np.ix_(inact, inact)] == np.eye(int(inact.sum())))


@given_or_params(max_examples=25, n=(8, 60), k_max=(2, 24), seed=(0, 10_000))
def test_chol_rank1_downdate_matches_fresh_factorization(n, k_max, seed):
    rng = np.random.default_rng(seed)
    k_act = int(rng.integers(1, k_max + 1))
    W, x, act = _padded_chol_case(n, k_max, k_act, seed)
    Wup = W + np.outer(x, x)
    L = np.linalg.cholesky(Wup)
    got, ok = ibm.chol_rank1_downdate(jnp.asarray(L, jnp.float32),
                                      jnp.asarray(x, jnp.float32))
    assert bool(ok), "downdate of an SPD-remaining matrix must not trip"
    want = np.linalg.cholesky(W)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-4, atol=2e-4)


def test_chol_rank1_roundtrip_and_t_variants():
    """update then downdate returns the original factor; the transposed
    precomputed-p forms agree with the solve-based public forms."""
    rng = np.random.default_rng(0)
    K = 12
    Z = (rng.random((50, K)) < 0.4).astype(np.float64)
    W = Z.T @ Z + 0.7 * np.eye(K)
    L = np.linalg.cholesky(W).astype(np.float32)
    x = (rng.random(K) < 0.5).astype(np.float32)
    L1 = ibm.chol_rank1_update(jnp.asarray(L), jnp.asarray(x))
    L2, ok = ibm.chol_rank1_downdate(L1, jnp.asarray(x))
    assert bool(ok)
    np.testing.assert_allclose(np.asarray(L2), L, rtol=1e-3, atol=1e-4)
    # _t forms with p = L^{-1} x
    import scipy.linalg as sla
    p = sla.solve_triangular(L, x, lower=True).astype(np.float32)
    Lt1 = ibm.chol_rank1_update_t(jnp.asarray(L.T.copy()), jnp.asarray(p))
    np.testing.assert_allclose(np.asarray(Lt1).T, np.asarray(L1),
                               rtol=1e-5, atol=1e-6)


def test_chol_rank1_downdate_canary_fires_on_pd_loss():
    """Downdating more mass than the matrix holds must flag ok=False."""
    K = 6
    L = jnp.asarray(np.linalg.cholesky(0.1 * np.eye(K)), jnp.float32)
    _, ok = ibm.chol_rank1_downdate(L, jnp.ones((K,), jnp.float32))
    assert not bool(ok)


@given_or_params(max_examples=25, k_max=(3, 20), d=(2, 16), seed=(0, 10_000))
def test_g_rank1_matches_recompute_under_masking(k_max, d, seed):
    """The carried G = HHᵀ rank-two move equals the fresh recompute after
    the matching rank-one H move — including exact zero padding on
    inactive rows/cols (the packed carry's contract, DESIGN.md §14)."""
    rng = np.random.default_rng(seed)
    k_act = int(rng.integers(1, k_max + 1))
    act = np.zeros(k_max, np.float64)
    act[np.sort(rng.choice(k_max, size=k_act, replace=False))] = 1.0
    H = rng.standard_normal((k_max, d)) * act[:, None]
    G = H @ H.T
    a = rng.standard_normal(k_max) * act  # callers mask the rank-one vector
    b = rng.standard_normal(d)
    got = np.asarray(ibm.g_rank1(
        jnp.asarray(G, jnp.float32), jnp.asarray(H, jnp.float32),
        jnp.asarray(a, jnp.float32), jnp.asarray(b, jnp.float32),
    ))
    Hn = H + np.outer(a, b)
    np.testing.assert_allclose(got, Hn @ Hn.T, rtol=2e-4, atol=2e-4)
    # padding transparency: inactive rows/cols stay exactly zero
    inact = act < 0.5
    assert np.all(got[inact] == 0) and np.all(got[:, inact] == 0)
    # symmetry is exact (the flip reads G rows as columns)
    np.testing.assert_array_equal(got, got.T)


def test_g_rank1_composes_with_sherman_morrison_move():
    """End-to-end shape of the packed row step's remove-row move: the SM
    update of H and the matching g_rank1 leave G consistent with H."""
    rng = np.random.default_rng(1)
    K, D = 9, 6
    Z = (rng.random((40, K)) < 0.5).astype(np.float64)
    X = rng.standard_normal((40, D))
    W = Z.T @ Z + 0.7 * np.eye(K)
    M = np.linalg.inv(W)
    H = M @ (Z.T @ X)
    G = H @ H.T
    z = Z[7]
    x = X[7]
    w = M @ z
    delta = 1.0 - z @ w
    wd = w / delta
    b = z @ H - x
    H1 = H + np.outer(wd, b)
    G1 = np.asarray(ibm.g_rank1(
        jnp.asarray(G, jnp.float32), jnp.asarray(H, jnp.float32),
        jnp.asarray(wd, jnp.float32), jnp.asarray(b, jnp.float32),
    ))
    np.testing.assert_allclose(G1, H1 @ H1.T, rtol=3e-4, atol=3e-4)


def test_live_buckets_and_pick_bucket_policy():
    assert ibm.live_buckets(64) == (8, 16, 32, 64)
    assert ibm.live_buckets(32) == (8, 16, 32)
    assert ibm.live_buckets(12) == (8, 12)
    assert ibm.live_buckets(8) == (8,)
    assert ibm.live_buckets(6) == (6,)
    with pytest.raises(ValueError):
        ibm.live_buckets(0)
    b64 = ibm.live_buckets(64)
    assert ibm.pick_bucket(b64, 2, 4) == 8
    assert ibm.pick_bucket(b64, 8, 4) == 16   # headroom forces the next rung
    assert ibm.pick_bucket(b64, 12, 4) == 16
    assert ibm.pick_bucket(b64, 30, 4) == 64
    assert ibm.pick_bucket(b64, 62, 4) == 64  # clamps at K_max
    assert ibm.pick_bucket(b64, 64, 4) == 64


@given_or_params(max_examples=25, k_max=(4, 24), seed=(0, 10_000))
def test_block_select_properties(k_max, seed):
    """The packed block = all live columns + lowest-index free slots,
    ascending; min_out bounds every out-of-block (all-free) index."""
    rng = np.random.default_rng(seed)
    n_live = int(rng.integers(0, k_max + 1))
    act = np.zeros(k_max, np.float32)
    act[np.sort(rng.choice(k_max, size=n_live, replace=False))] = 1.0
    B = int(rng.integers(max(1, n_live), k_max + 1))
    cols, min_out = ibm.block_select(jnp.asarray(act), B)
    cols, min_out = np.asarray(cols), int(min_out)
    assert cols.shape == (B,)
    assert np.all(np.diff(cols) > 0)  # strictly ascending => unique
    live = set(np.flatnonzero(act > 0.5).tolist())
    assert live <= set(cols.tolist())  # every live column is in the block
    free_sorted = np.flatnonzero(act <= 0.5)
    want_free = set(free_sorted[:B - n_live].tolist())
    assert set(cols.tolist()) == live | want_free
    outside = sorted(set(range(k_max)) - set(cols.tolist()))
    if outside:
        assert min_out == outside[0]
        assert all(act[j] <= 0.5 for j in outside)  # out-of-block all free
        assert all(f >= min_out for f in free_sorted[B - n_live:])
    else:
        assert min_out == k_max  # sentinel: block covers everything


def test_chol_moves_commute_with_block_packing():
    """With identity-decoupled padding, the packed principal block's
    Cholesky factor equals the gathered rows/cols of the full factor,
    and the rank-one moves commute with the gather — the property that
    makes bucket repack a pure permutation + refresh (DESIGN.md §14)."""
    rng = np.random.default_rng(3)
    k_max, n = 14, 50
    W, x, act = _padded_chol_case(n, k_max, 9, 3)
    cols = np.flatnonzero(act > 0.5)
    ix = np.ix_(cols, cols)
    L = np.linalg.cholesky(W)
    Lp = np.linalg.cholesky(W[ix])
    np.testing.assert_allclose(L[ix], Lp, rtol=1e-12, atol=1e-12)
    full = np.asarray(ibm.chol_rank1_update(
        jnp.asarray(L, jnp.float32), jnp.asarray(x, jnp.float32)))
    packed = np.asarray(ibm.chol_rank1_update(
        jnp.asarray(Lp, jnp.float32), jnp.asarray(x[cols], jnp.float32)))
    np.testing.assert_allclose(full[ix], packed, rtol=2e-5, atol=2e-5)
    dn_full, ok_f = ibm.chol_rank1_downdate(
        jnp.asarray(full), jnp.asarray(x, jnp.float32))
    dn_packed, ok_p = ibm.chol_rank1_downdate(
        jnp.asarray(packed), jnp.asarray(x[cols], jnp.float32))
    assert bool(ok_f) and bool(ok_p)
    np.testing.assert_allclose(np.asarray(dn_full)[ix],
                               np.asarray(dn_packed), rtol=2e-4, atol=2e-4)


def test_a_posterior_matches_conjugate_formula():
    rng = np.random.default_rng(1)
    N, D, K, K_max = 40, 6, 3, 8
    Z = (rng.random((N, K)) < 0.5).astype(np.float64)
    A_true = rng.standard_normal((K, D))
    X = Z @ A_true + 0.2 * rng.standard_normal((N, D))
    sx, sa = 0.4, 1.0

    W = Z.T @ Z + (sx / sa) ** 2 * np.eye(K)
    want_mean = np.linalg.solve(W, Z.T @ X)

    Zp = np.zeros((N, K_max), np.float32)
    Zp[:, :K] = Z
    act = np.zeros(K_max, np.float32)
    act[:K] = 1
    mean, M = ibm.a_posterior(
        jnp.asarray(Zp.T @ Zp, jnp.float32),
        jnp.asarray(Zp.T @ X, jnp.float32),
        jnp.asarray(act),
        jnp.float32(sx),
        jnp.float32(sa),
    )
    np.testing.assert_allclose(np.asarray(mean)[:K], want_mean, rtol=1e-3,
                               atol=1e-4)
    # inactive rows must be exactly zero
    assert np.all(np.asarray(mean)[K:] == 0)


def test_a_posterior_draw_moments():
    """Monte-Carlo check that draws have the right mean/marginal variance."""
    rng = np.random.default_rng(2)
    N, D, K, K_max = 60, 4, 2, 4
    Z = (rng.random((N, K)) < 0.6).astype(np.float64)
    X = Z @ rng.standard_normal((K, D)) + 0.3 * rng.standard_normal((N, D))
    sx, sa = 0.5, 1.0
    W = Z.T @ Z + (sx / sa) ** 2 * np.eye(K)
    M = np.linalg.inv(W)
    want_mean = M @ Z.T @ X

    Zp = np.zeros((N, K_max), np.float32)
    Zp[:, :K] = Z
    act = np.zeros(K_max, np.float32)
    act[:K] = 1
    draws = []
    for i in range(400):
        d = ibm.a_posterior_draw(
            jax.random.key(i),
            jnp.asarray(Zp.T @ Zp, jnp.float32),
            jnp.asarray(Zp.T @ X, jnp.float32),
            jnp.asarray(act), jnp.float32(sx), jnp.float32(sa),
        )
        draws.append(np.asarray(d)[:K])
    draws = np.stack(draws)
    np.testing.assert_allclose(draws.mean(0), want_mean, atol=0.05)
    want_var = sx**2 * np.diag(M)
    np.testing.assert_allclose(
        draws.var(0).mean(axis=1), want_var, rtol=0.35
    )


def test_inverse_gamma_draw_moments():
    a, b = 5.0, 3.0
    key = jax.random.key(0)
    xs = jax.vmap(
        lambda k: ibm.inverse_gamma_draw(k, jnp.float32(a), jnp.float32(b))
    )(jax.random.split(key, 4000))
    want_mean = b / (a - 1)
    assert np.isclose(float(jnp.mean(xs)), want_mean, rtol=0.1)
