"""Unit tests for the convergence-diagnostics module (core/ibp/convergence):
calibration on analytically understood chains — iid, shifted, AR(1)."""
import numpy as np
import pytest

from repro.core.ibp import convergence as cv


@pytest.fixture(scope="module")
def iid():
    return np.random.default_rng(0).standard_normal((4, 500))


def _ar1(rho, C=4, T=1000, seed=1):
    rng = np.random.default_rng(seed)
    x = np.zeros((C, T))
    for t in range(1, T):
        x[:, t] = rho * x[:, t - 1] + rng.standard_normal(C)
    return x


def test_split_rhat_iid_near_one(iid):
    assert abs(cv.split_rhat(iid) - 1.0) < 0.02


def test_split_rhat_flags_disjoint_chains(iid):
    shifted = iid + 5.0 * np.arange(4)[:, None]
    assert cv.split_rhat(shifted) > 1.5


def test_split_rhat_flags_within_chain_drift():
    # a single chain that jumps halfway: caught by the half-split
    x = np.concatenate([np.zeros(250), np.ones(250)])[None, :]
    x = x + 0.01 * np.random.default_rng(2).standard_normal((1, 500))
    assert cv.split_rhat(x) > 1.5


def test_ess_iid_near_n(iid):
    n = iid.size
    assert 0.7 * n <= cv.ess(iid) <= n


def test_ess_ar1_matches_theory():
    # AR(1) with coefficient rho has tau = (1+rho)/(1-rho)
    rho = 0.9
    x = _ar1(rho, C=4, T=4000)
    n = x.size
    expect = n * (1 - rho) / (1 + rho)
    got = cv.ess(x)
    assert 0.5 * expect <= got <= 2.0 * expect, (got, expect)


def test_mcse_iid_calibrated(iid):
    # sd/sqrt(n) for iid standard normal
    assert cv.mcse(iid) == pytest.approx(1.0 / np.sqrt(iid.size), rel=0.2)


def test_geweke_z_stationary_vs_drift(iid):
    assert abs(cv.geweke_z(iid)) < 3.5
    drift = iid + np.linspace(0, 3, iid.shape[1])[None, :]
    assert abs(cv.geweke_z(drift)) > 4.0


def test_mean_diff_z_calibrated(iid):
    rng = np.random.default_rng(3)
    other = rng.standard_normal((4, 500))
    assert abs(cv.mean_diff_z(iid, other)) < 4.0       # same mean
    assert abs(cv.mean_diff_z(iid, other + 1.0)) > 10  # separated means


def test_constant_traces_are_defined():
    const = np.ones((2, 100))
    assert np.isnan(cv.split_rhat(const))   # no variance: undefined, not crash
    assert cv.mcse(const) == 0.0
    assert cv.geweke_z(const) == 0.0
    assert cv.mean_diff_z(const, const) == 0.0
    assert np.isinf(cv.mean_diff_z(const, const + 1.0))


def test_one_dim_trace_accepted(iid):
    flat = iid[0]
    assert cv.ess(flat) > 100
    s = cv.summarize(flat, "x")
    assert set(s) == {"x_mean", "x_sd", "x_rhat", "x_ess", "x_mcse"}


def test_bad_shape_rejected():
    with pytest.raises(ValueError):
        cv.split_rhat(np.zeros((2, 3, 4)))
