import os
import sys

# tests run single-device (the dry-run forces 512 devices in its OWN process)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
