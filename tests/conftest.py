import os
import sys

# tests run single-device (the dry-run forces 512 devices in its OWN process)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-chain statistical tests (run in the non-blocking CI job; "
        "deselect with -m 'not slow')",
    )
