"""Integration tests: all three samplers recover the Cambridge features, and
hybrid (the paper's algorithm) agrees with the collapsed baseline on
posterior statistics (asymptotic-exactness check at small scale)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.ibp import (
    IBPHypers,
    SamplerSpec,
    build_sampler,
    collapsed_sweep,
    init_state,
    uncollapsed_step,
)
from repro.core.ibp.diagnostics import match_features
from repro.data import cambridge_data


@pytest.fixture(scope="module")
def data():
    X, Ztrue, Atrue = cambridge_data(N=120, sigma_n=0.4, seed=3)
    return jnp.asarray(X), Ztrue, Atrue


def test_collapsed_recovers_features(data):
    X, _, Atrue = data
    hyp = IBPHypers()
    st = init_state(jax.random.key(0), X.shape[0], 36, K_max=16, K_init=1)
    for _ in range(80):
        st = collapsed_sweep(st, X, hyp)
    K = int(st.active.sum())
    assert 3 <= K <= 9, K
    assert 0.3 <= float(st.sigma_x) <= 0.6
    # recover A from the posterior mean given Z
    from repro.core.ibp import math as ibm
    Z = st.Z * st.active[None, :]
    mean, _ = ibm.a_posterior(Z.T @ Z, Z.T @ X, st.active, st.sigma_x,
                              st.sigma_a)
    act = np.asarray(st.active) > 0.5
    _, sse = match_features(np.asarray(mean)[act], Atrue)
    assert sse < 2.0, sse


def test_hybrid_recovers_features(data):
    X, _, Atrue = data
    s = build_sampler(SamplerSpec(P=4, K_max=16, K_tail=6, K_init=4, L=5),
                      IBPHypers(), np.asarray(X))
    gs, ss = s.init(jax.random.key(1))
    for _ in range(80):
        gs, ss = s.step(gs, ss)
    K = int(gs.active.sum())
    assert 3 <= K <= 9, K
    assert 0.3 <= float(gs.sigma_x) <= 0.6
    act = np.asarray(gs.active) > 0.5
    _, sse = match_features(np.asarray(gs.A)[act], Atrue)
    assert sse < 2.0, sse


def test_uncollapsed_fits_with_fixed_truncation(data):
    X, _, _ = data
    hyp = IBPHypers()
    st = init_state(jax.random.key(2), X.shape[0], 36, K_max=8, K_init=8)
    # seed features from data rows (same trick the hybrid uses)
    st = type(st)(
        Z=st.Z, A=X[:8] + 0.01, pi=st.pi, active=st.active, tail=st.tail,
        alpha=st.alpha, sigma_x=st.sigma_x, sigma_a=st.sigma_a, key=st.key,
        p_prime=st.p_prime, it=st.it,
    )
    for _ in range(60):
        st = uncollapsed_step(st, X, hyp)
    assert 0.25 <= float(st.sigma_x) <= 0.7


def test_hybrid_matches_collapsed_posterior_stats():
    """Asymptotic exactness: E[K+], E[sigma_x] agree across samplers within
    MC error on a small problem (the paper's core correctness claim)."""
    X, _, _ = cambridge_data(N=60, sigma_n=0.4, seed=7)
    Xj = jnp.asarray(X)
    hyp = IBPHypers()

    # collapsed chain
    st = init_state(jax.random.key(0), 60, 36, K_max=12, K_init=1)
    cK, csx = [], []
    for i in range(150):
        st = collapsed_sweep(st, Xj, hyp)
        if i >= 50:
            cK.append(float(st.active.sum()))
            csx.append(float(st.sigma_x))

    # hybrid chain (P=3)
    s = build_sampler(SamplerSpec(P=3, K_max=12, K_tail=6, K_init=4, L=5),
                      hyp, X)
    gs, ss = s.init(jax.random.key(1))
    hK, hsx = [], []
    for i in range(150):
        gs, ss = s.step(gs, ss)
        if i >= 50:
            hK.append(float(gs.active.sum()))
            hsx.append(float(gs.sigma_x))

    # agreement within loose MC tolerance
    assert abs(np.mean(cK) - np.mean(hK)) < 2.0, (np.mean(cK), np.mean(hK))
    assert abs(np.mean(csx) - np.mean(hsx)) < 0.08, (np.mean(csx), np.mean(hsx))


def test_hybrid_single_processor_runs():
    """P=1 degenerate case (the paper reports P=1 beats collapsed on speed)."""
    X, _, _ = cambridge_data(N=40, seed=9)
    s = build_sampler(SamplerSpec(P=1, K_max=12, K_tail=6, K_init=4, L=5),
                      IBPHypers(), X)
    gs, ss = s.init(jax.random.key(0))
    for _ in range(30):
        gs, ss = s.step(gs, ss)
    assert int(gs.active.sum()) >= 1
    assert np.isfinite(float(gs.sigma_x))


def test_hybrid_pallas_backend_matches_jnp_statistically():
    """The Pallas gibbs_flip backend drives the sampler to the same posterior
    region (identical contract, different uniforms consumption order)."""
    X, _, _ = cambridge_data(N=48, seed=11)
    outs = {}
    for backend in ("jnp", "pallas"):
        s = build_sampler(
            SamplerSpec(P=2, K_max=12, K_tail=6, K_init=4, L=3,
                        backend=backend),
            IBPHypers(), X,
        )
        gs, ss = s.init(jax.random.key(3))
        for _ in range(40):
            gs, ss = s.step(gs, ss)
        outs[backend] = (int(gs.active.sum()), float(gs.sigma_x))
    assert abs(outs["jnp"][0] - outs["pallas"][0]) <= 2
    assert abs(outs["jnp"][1] - outs["pallas"][1]) < 0.15


def test_promote_tail_full_occupancy_drops_not_corrupts():
    """Regression (spec bugfix companion): promoting a live tail into a
    FULLY-occupied instantiated set must drop every tail feature — and
    must not scribble on live columns or the active mask. (The spec now
    rejects K_tail > K_max outright, so full occupancy is the only way
    promotion can run out of slots.)"""
    from repro.core.ibp.hybrid import promote_tail

    rng = np.random.default_rng(5)
    N_p, K_max, K_tail = 12, 6, 4
    Z = jnp.asarray((rng.random((N_p, K_max)) < 0.5).astype(np.float32))
    active = jnp.ones((K_max,), jnp.float32)          # no free slots
    Z_tail = jnp.asarray(
        (rng.random((N_p, K_tail)) < 0.5).astype(np.float32))
    tail_g = jnp.asarray([1.0, 1.0, 0.0, 1.0], jnp.float32)
    Z_new, active_new, n_drop = promote_tail(Z, Z_tail, tail_g, active)
    assert int(n_drop) == 3                           # every live tail dropped
    np.testing.assert_array_equal(np.asarray(Z_new), np.asarray(Z))
    np.testing.assert_array_equal(np.asarray(active_new), np.asarray(active))


def test_promote_tail_partial_occupancy_keeps_what_fits():
    """With fewer free slots than live tails, the lowest-rank tails land
    in the free slots (existing live columns untouched) and the overflow
    is counted in n_drop."""
    from repro.core.ibp.hybrid import promote_tail

    rng = np.random.default_rng(6)
    N_p, K_max = 10, 5
    Z = jnp.asarray((rng.random((N_p, K_max)) < 0.5).astype(np.float32))
    active = jnp.asarray([1.0, 0.0, 1.0, 1.0, 0.0], jnp.float32)  # 2 free
    Z_keep = Z * active[None, :]
    Z = Z_keep                                        # dead cols are zero
    Z_tail = jnp.asarray((rng.random((N_p, 3)) < 0.5).astype(np.float32))
    tail_g = jnp.ones((3,), jnp.float32)              # 3 live tails, 2 fit
    Z_new, active_new, n_drop = promote_tail(Z, Z_tail, tail_g, active)
    assert int(n_drop) == 1
    np.testing.assert_array_equal(np.asarray(active_new),
                                  np.ones((K_max,), np.float32))
    # promoted columns landed in the free slots (1 and 4), in tail order
    np.testing.assert_array_equal(np.asarray(Z_new[:, 1]),
                                  np.asarray(Z_tail[:, 0]))
    np.testing.assert_array_equal(np.asarray(Z_new[:, 4]),
                                  np.asarray(Z_tail[:, 1]))
    # live columns untouched
    for k in (0, 2, 3):
        np.testing.assert_array_equal(np.asarray(Z_new[:, k]),
                                      np.asarray(Z_keep[:, k]))
