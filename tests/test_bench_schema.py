"""BENCH_*.json schema lint + unified-core no-regression gate
(benchmarks/bench_schema.py, wired into `benchmarks.run --smoke`)."""
import copy
import json
import os

import pytest

from benchmarks import bench_schema as bs

REPO_BENCH = sorted(
    p for p in os.listdir(bs.REPO_ROOT) if p.startswith("BENCH_")
)


@pytest.fixture()
def committed():
    """The committed trajectory file — must exist and parse."""
    assert REPO_BENCH, "repo must carry a BENCH_*.json trajectory"
    with open(os.path.join(bs.REPO_ROOT, REPO_BENCH[-1])) as fh:
        return json.load(fh)


def test_committed_trajectory_is_clean(committed):
    assert bs.lint_payload(committed) == []
    assert bs.lint_repo() == []


def test_lint_fails_closed_on_missing_files(tmp_path):
    errs = bs.lint_repo(str(tmp_path))
    assert len(errs) == 1 and "fail closed" in errs[0]


def test_unknown_section_rejected(committed):
    bad = dict(committed, surprise_section=[{"x": 1}])
    errs = bs.lint_payload(bad)
    assert any("unregistered section" in e for e in errs)


def test_missing_required_key_rejected(committed):
    bad = copy.deepcopy(committed)
    del bad["collapsed_sweep"]["results"][0]["speedup"]
    errs = bs.lint_payload(bad)
    assert any("missing required key 'speedup'" in e for e in errs)


def test_nonfinite_and_nonpositive_metrics_rejected(committed):
    bad = copy.deepcopy(committed)
    bad["occupancy_sweep"]["results"][0]["packed_rows_per_s"] = float("nan")
    bad["collapsed_sweep"]["results"][0]["ref_rows_per_s"] = 0.0
    errs = bs.lint_payload(bad)
    assert any("non-finite" in e for e in errs)
    assert any("non-positive" in e for e in errs)


def test_empty_row_list_rejected(committed):
    bad = copy.deepcopy(committed)
    bad["collapsed_sweep"]["results"] = []
    errs = bs.lint_payload(bad)
    assert any("empty row list" in e for e in errs)


def test_wrong_type_rejected(committed):
    bad = copy.deepcopy(committed)
    bad["device_count"] = "two"
    errs = bs.lint_payload(bad)
    assert any("device_count" in e for e in errs)


def test_unreadable_file_reported(tmp_path):
    (tmp_path / "BENCH_2026-01-01.json").write_text("{not json")
    errs = bs.lint_repo(str(tmp_path))
    assert len(errs) == 1 and "unreadable" in errs[0]


# --- unified-core no-regression gate (DESIGN.md §12) -----------------------


def test_gate_passes_at_recorded_speed(committed):
    cur = committed["occupancy_sweep"]
    assert bs.unpacked_core_regression(cur) == []


def test_gate_trips_on_top_bucket_slowdown(committed):
    """Unpacked (top-bucket unified core) losing ground RELATIVE to the
    same-run packed timing is the regression signature."""
    cur = copy.deepcopy(committed["occupancy_sweep"])
    for r in cur["results"]:
        r["unpacked_rows_per_s"] *= 0.4
    errs = bs.unpacked_core_regression(cur)
    assert len(errs) == len(cur["results"])
    assert all("unified core regressed" in e for e in errs)


def test_gate_ignores_uniform_machine_slowdown(committed):
    """A loaded CI box slows BOTH modes — the load-invariant ratio must
    not trip (the fast>=2x-ref same-run gate owns uniform slowdowns)."""
    cur = copy.deepcopy(committed["occupancy_sweep"])
    for r in cur["results"]:
        r["unpacked_rows_per_s"] *= 0.35
        r["packed_rows_per_s"] *= 0.35
    assert bs.unpacked_core_regression(cur) == []


def test_gate_fails_closed_without_comparable_rows(committed, tmp_path):
    cur = committed["occupancy_sweep"]
    # no recorded trajectory at all
    errs = bs.unpacked_core_regression(cur, root=str(tmp_path))
    assert errs and "fail closed" in errs[0]
    # recorded file exists but at different sizes -> not comparable
    other = copy.deepcopy(committed)
    other["occupancy_sweep"]["N"] = committed["occupancy_sweep"]["N"] * 2
    (tmp_path / "BENCH_2026-01-01.json").write_text(json.dumps(other))
    errs = bs.unpacked_core_regression(cur, root=str(tmp_path))
    assert errs and "fail closed" in errs[0]
    # and an empty current sweep can never pass vacuously
    errs = bs.unpacked_core_regression({}, root=str(tmp_path))
    assert errs and "fail closed" in errs[0]


def test_gate_skips_todays_merge_target(committed, tmp_path):
    """The file this run merges into must not serve as its own baseline."""
    (tmp_path / "BENCH_2026-02-02.json").write_text(json.dumps(committed))
    cur = committed["occupancy_sweep"]
    errs = bs.unpacked_core_regression(cur, root=str(tmp_path),
                                       skip_date="2026-02-02")
    assert errs and "fail closed" in errs[0]  # only file was skipped
    assert bs.unpacked_core_regression(cur, root=str(tmp_path)) == []
