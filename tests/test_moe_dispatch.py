"""Property tests for the MoE dispatch machinery and the fused-sync SSE
identity — the §Perf-critical code paths, checked at the math level
(mesh-level equivalence is covered in test_distributed.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp_compat import given_or_params

from repro.core.ibp import math as ibm
from repro.models.moe import _dispatch_tables, _route


def _routing(T, E, k, seed):
    rng = np.random.default_rng(seed)
    probs = rng.random((T, E)).astype(np.float32)
    probs /= probs.sum(1, keepdims=True)
    gv, ei = jax.lax.top_k(jnp.asarray(probs), k)
    gv = gv / jnp.sum(gv, axis=-1, keepdims=True)
    counts = jnp.zeros((E,), jnp.float32).at[ei.reshape(-1)].add(1.0)
    return gv, ei, counts


@given_or_params(max_examples=20, T=(4, 64), E=(2, 16), k=(1, 3),
                 cf=(0.5, 4.0), seed=(0, 99))
def test_dispatch_table_invariants(T, E, k, cf, seed):
    k = min(k, E)
    gv, ei, counts = _routing(T, E, k, seed)
    C = max(1, int(T * k / E * cf))
    table, gtable = _dispatch_tables(ei, gv, counts, E, C, T)
    table = np.asarray(table)
    gtable = np.asarray(gtable)
    # every slot is either a valid token id or the zero-row sentinel T
    assert table.min() >= 0 and table.max() <= T
    # gates are zero exactly on sentinel slots
    assert np.all((gtable == 0) | (table != T))
    # no token appears more than once in the same expert's slots
    for e in range(E):
        toks = table[e][table[e] != T]
        assert len(np.unique(toks)) == len(toks)
    # each kept (token, expert) pair carries its routing gate
    gv_np, ei_np = np.asarray(gv), np.asarray(ei)
    for e in range(E):
        for c in range(C):
            t = table[e, c]
            if t == T:
                continue
            j = list(ei_np[t]).index(e)
            np.testing.assert_allclose(gtable[e, c], gv_np[t, j], rtol=1e-6)
    # capacity respected per expert; nothing dropped when cf is generous
    if C >= T * k:
        kept = (table != T).sum()
        assert kept == T * k


@given_or_params(max_examples=15, N=(4, 40), D=(2, 12), K=(1, 8),
                 seed=(0, 99))
def test_fused_sync_sse_identity(N, D, K, seed):
    """||X - Z A||^2 == tr(XtX) - 2<A, ZtX> + <A, (ZtZ) A> with masks,
    the identity that lets the fused sync drop the dedicated SSE reduce."""
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((N, D)).astype(np.float32)
    Z = (rng.random((N, K)) < 0.4).astype(np.float32)
    A = rng.standard_normal((K, D)).astype(np.float32)
    active = (rng.random(K) < 0.7).astype(np.float32)
    A = A * active[:, None]
    direct = float(np.sum((X - (Z * active[None, :]) @ A) ** 2))
    ZtX = (Z.T @ X) * active[:, None]
    ZtZ = (Z.T @ Z) * np.asarray(ibm.mask_outer(jnp.asarray(active)))
    ident = float(np.sum(X * X) - 2.0 * np.sum(A * ZtX)
                  + np.sum(A * (ZtZ @ A)))
    np.testing.assert_allclose(ident, direct, rtol=2e-4, atol=2e-3)


def test_route_aux_ingredients_match_onehot():
    """_route's counts / prob sums equal the dense one-hot computation."""
    T, E, k = 32, 8, 2
    rng = np.random.default_rng(0)
    xt = jnp.asarray(rng.standard_normal((T, 16)), jnp.float32)
    router = jnp.asarray(rng.standard_normal((16, E)), jnp.float32)
    gv, ei, counts, psum = _route(xt, router, E, k)
    probs = jax.nn.softmax((xt @ router).astype(jnp.float32), axis=-1)
    onehot = jax.nn.one_hot(ei, E).sum(1)  # (T, E)
    np.testing.assert_allclose(np.asarray(counts),
                               np.asarray(onehot.sum(0)), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(psum),
                               np.asarray(probs.sum(0)), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(gv.sum(1)), 1.0, rtol=1e-5)
