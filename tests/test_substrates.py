"""Checkpointing, optimizer, data pipeline, driver fault-tolerance tests."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore, save_pytree
from repro.core.ibp import IBPHypers
from repro.data import cambridge_data, shard_rows, train_eval_split
from repro.data.synthetic_lm import SyntheticLM
from repro.optim import AdamW, cosine_schedule
from repro.runtime import DriverConfig, MCMCDriver


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "k": jax.random.key(3),
        "nested": {"b": jnp.ones((4,), jnp.bfloat16)},
    }
    save_pytree(str(tmp_path), tree, 7)
    assert latest_step(str(tmp_path)) == 7
    out, step = restore(str(tmp_path), tree)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    assert out["nested"]["b"].dtype == jnp.bfloat16
    # key round-trips usably
    assert jnp.all(
        jax.random.uniform(out["k"], (3,)) == jax.random.uniform(tree["k"], (3,))
    )


def test_checkpoint_retention(tmp_path):
    tree = {"x": jnp.zeros(3)}
    for s in range(1, 6):
        save_pytree(str(tmp_path), tree, s, keep=2)
    from repro.checkpoint.npz import all_steps
    assert all_steps(str(tmp_path)) == [4, 5]


def test_adamw_converges_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.ones((4,)) * 5.0}
    state = opt.init(params)

    @jax.jit
    def step(p, s):
        g = jax.grad(lambda p: jnp.sum((p["w"] - 2.0) ** 2))(p)
        return opt.update(p, g, s)

    for _ in range(300):
        params, state = step(params, state)
    np.testing.assert_allclose(np.asarray(params["w"]), 2.0, atol=1e-2)


def test_int8_grad_compression_still_converges():
    opt = AdamW(lr=0.1, weight_decay=0.0, grad_compress="int8")
    params = {"w": jnp.ones((64,)) * 5.0}
    state = opt.init(params)

    @jax.jit
    def step(p, s):
        g = jax.grad(lambda p: jnp.sum((p["w"] - 2.0) ** 2))(p)
        return opt.update(p, g, s)

    for _ in range(400):
        params, state = step(params, state)
    np.testing.assert_allclose(np.asarray(params["w"]), 2.0, atol=0.1)


def test_schedule_shapes():
    f = cosine_schedule(1.0, 10, 100)
    assert float(f(jnp.asarray(0))) == 0.0
    assert float(f(jnp.asarray(10))) == pytest.approx(1.0)
    assert float(f(jnp.asarray(100))) == pytest.approx(0.1, abs=1e-3)


def test_synthetic_lm_determinism_and_sharding():
    d = SyntheticLM(vocab=1000, seq_len=64, global_batch=8, seed=1, n_shards=2)
    b0 = d.batch(3, shard=0)["tokens"]
    b0b = d.batch(3, shard=0)["tokens"]
    b1 = d.batch(3, shard=1)["tokens"]
    np.testing.assert_array_equal(b0, b0b)
    assert not np.array_equal(b0, b1)
    assert b0.shape == (4, 64)
    assert b0.max() < 1000


def test_train_eval_split_disjoint():
    X, _, _ = cambridge_data(N=100, seed=0)
    tr, ev = train_eval_split(X, eval_frac=0.2, seed=0)
    assert tr.shape[0] == 80 and ev.shape[0] == 20


def test_driver_crash_restart_and_elastic(tmp_path):
    X, _, _ = cambridge_data(N=48, seed=2)
    cfg = DriverConfig(P=4, K_max=16, K_tail=6, n_iters=20, ckpt_every=5,
                       eval_every=10, ckpt_dir=str(tmp_path))
    drv = MCMCDriver(X, cfg)
    with pytest.raises(RuntimeError, match="injected crash"):
        drv.run(crash_at=12)
    assert latest_step(str(tmp_path)) == 10

    # resume completes
    drv2 = MCMCDriver(X, cfg)
    gs, ss = drv2.run()
    assert int(gs.it) == 20

    # elastic: restart the same checkpoint with P=2
    cfg2 = DriverConfig(P=2, K_max=16, K_tail=6, n_iters=25, ckpt_every=5,
                        eval_every=10, ckpt_dir=str(tmp_path))
    gs3, ss3 = MCMCDriver(X, cfg2).run()
    assert ss3.Z.shape[0] == 2
    assert int(gs3.it) == 25


def test_driver_resume_is_deterministic(tmp_path):
    """Same seed + checkpoint -> bitwise-identical continuation."""
    X, _, _ = cambridge_data(N=32, seed=5)
    cfg = DriverConfig(P=2, K_max=12, K_tail=4, n_iters=10, ckpt_every=5,
                       eval_every=100, ckpt_dir=str(tmp_path))
    gs_a, ss_a = MCMCDriver(X, cfg).run()          # runs 0..10 w/ ckpt at 5, 10

    shutil.rmtree(tmp_path)
    cfg_half = DriverConfig(P=2, K_max=12, K_tail=4, n_iters=5, ckpt_every=5,
                            eval_every=100, ckpt_dir=str(tmp_path))
    MCMCDriver(X, cfg_half).run()                   # 0..5 + ckpt
    gs_b, ss_b = MCMCDriver(X, cfg).run()           # resume 5..10
    np.testing.assert_array_equal(np.asarray(ss_a.Z), np.asarray(ss_b.Z))
    assert float(gs_a.sigma_x) == float(gs_b.sigma_x)
